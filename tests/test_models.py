"""Model-substrate correctness: decode==full-forward per family, chunked
algorithms vs sequential references, GQA layouts, MoE strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.models.mamba2 import ssd_chunked
from repro.models.rwkv6 import wkv6_chunked

BASE = dict(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=97, dtype="float32", remat="none")

FAMILIES = {
    "dense": ModelConfig(name="t-dense", family="dense", **BASE),
    "gemma2": ModelConfig(name="t-g2", family="dense", attn_pattern="local_global",
                          sliding_window=8, attn_softcap=50.0, logit_softcap=30.0,
                          sandwich_norms=True, embed_scale=True, **BASE),
    "moe": ModelConfig(name="t-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                       moe_strategy="dense", **BASE),
    "rwkv": ModelConfig(name="t-rwkv", family="ssm", rwkv_headdim=16, **BASE),
    "zamba": ModelConfig(name="t-z", family="hybrid", attn_every=2, ssm_state=16,
                         mamba_headdim=16, **BASE),
    "vlm": ModelConfig(name="t-vlm", family="vlm", rope_type="mrope",
                       mrope_sections=(4, 2, 2), **BASE),
}


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_decode_matches_full_forward(name):
    cfg = FAMILIES[name]
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    full = m.logits(p, {"tokens": toks})
    assert not bool(jnp.any(jnp.isnan(full)))
    logits, cache, stats = m.prefill(p, {"tokens": toks[:, :16]}, max_len=32)
    lg, cache = m.decode_step(p, toks[:, 16:17], cache, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 16]), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, :16]), atol=2e-4, rtol=1e-3)


def test_encdec_decode_matches_full():
    cfg = ModelConfig(name="t-w", family="encdec", is_encoder_decoder=True,
                      n_enc_layers=2, n_layers=2, gated_ffn=False, ffn_act="gelu",
                      rope_type="none", max_positions=64, d_model=64, n_heads=4,
                      n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=97,
                      dtype="float32", remat="none")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    frames = jax.random.normal(jax.random.key(2), (2, 8, 64), jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, 97)
    full = m.logits(p, {"frames": frames, "tokens": toks})
    logits, cache, stats = m.prefill(p, {"frames": frames, "tokens": toks[:, :16]}, max_len=32)
    lg, _ = m.decode_step(p, toks[:, 16:17], cache, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 16]), atol=2e-4, rtol=1e-3)


def test_gqa_layouts_equivalent():
    cfg_g = ModelConfig(name="g", family="dense", gqa_layout="grouped",
                        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
                        d_ff=128, vocab_size=97, dtype="float32", remat="none")
    m = build_model(cfg_g)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    lg = m.logits(p, {"tokens": toks})
    lr = build_model(cfg_g.replace(gqa_layout="repeated")).logits(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lr), atol=2e-5, rtol=1e-4)


def test_chunked_attention_exact():
    cfg_d = FAMILIES["gemma2"].replace(attn_chunk=10**9)
    cfg_c = cfg_d.replace(attn_chunk=8)
    m = build_model(cfg_d)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, 97)
    ld = m.logits(p, {"tokens": toks})
    lc = build_model(cfg_c).logits(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc), atol=2e-4, rtol=1e-3)


def test_moe_dropping_matches_dense_at_high_capacity():
    cfg = FAMILIES["moe"].replace(capacity_factor=8.0)
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    ld = m.logits(p, {"tokens": toks})
    lc = build_model(cfg.replace(moe_strategy="dropping", moe_chunk=8)).logits(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc), atol=2e-5, rtol=1e-4)


def _ssd_sequential(xh, dt, a, Bm, Cm):
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    state = np.zeros((B, H, N, P), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    xh, dt, Bm, Cm = map(lambda t: np.asarray(t, np.float64), (xh, dt, Bm, Cm))
    a = np.asarray(a, np.float64)
    for t in range(S):
        decay = np.exp(dt[:, t] * a)  # (B,H)
        upd = np.einsum("bN,bhp,bh->bhNp", Bm[:, t], xh[:, t], dt[:, t])
        state = state * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bN,bhNp->bhp", Cm[:, t], state)
    return ys, state


def test_ssd_chunked_vs_sequential():
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 32, 3, 4, 5
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y, st = ssd_chunked(xh, dt, a, Bm, Cm, chunk=8)
    y_ref, st_ref = _ssd_sequential(xh, dt, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4, rtol=1e-4)


def _wkv6_sequential(r, k, v, logw, u):
    B, S, H, P = r.shape
    r, k, v, logw = map(lambda t: np.asarray(t, np.float64), (r, k, v, logw))
    u = np.asarray(u, np.float64)
    state = np.zeros((B, H, P, P), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    for t in range(S):
        kt, vt, rt = k[:, t], v[:, t], r[:, t]
        ys[:, t] = np.einsum("bhp,bhpv->bhv", rt, state) + np.einsum(
            "bhp,hp,bhp,bhv->bhv", rt, u, kt, vt)
        state = state * np.exp(logw[:, t])[..., None] + np.einsum("bhp,bhv->bhpv", kt, vt)
    return ys, state


def test_wkv6_chunked_vs_sequential():
    rng = np.random.default_rng(1)
    B, S, H, P = 2, 32, 2, 4
    r = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    logw = jnp.asarray(-rng.uniform(1e-4, 0.5, size=(B, S, H, P)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, P)), jnp.float32)
    y, st = wkv6_chunked(r, k, v, logw, u, chunk=8)
    y_ref, st_ref = _wkv6_sequential(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4, rtol=1e-4)
