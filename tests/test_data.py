"""Data pipeline: determinism, resumability, host sharding."""
import numpy as np

from repro.data.pipeline import PackedLM, PipelineState
from repro.data.synthetic import CorpusConfig, SyntheticCorpus, shifted_corpus
from repro.data.tokenizer import decode, encode


def test_tokenizer_roundtrip():
    s = "hello world, tofu banana!"
    assert decode(encode(s)) == s


def test_corpus_deterministic():
    c1, c2 = SyntheticCorpus(), SyntheticCorpus()
    assert c1.document(42) == c2.document(42)
    assert c1.document(1) != c1.document(2)


def test_shifted_corpus_differs():
    assert SyntheticCorpus().document(0) != shifted_corpus().document(0)


def test_pipeline_resume_bit_identical():
    corpus = SyntheticCorpus()
    p1 = PackedLM(corpus, batch=2, seq=64)
    batches = [p1.next_batch() for _ in range(5)]
    state = PipelineState.from_dict(p1.state.to_dict())
    # fresh pipeline fast-forwarded via saved state reproduces the stream
    p2 = PackedLM(corpus, batch=2, seq=64, state=state)
    b1 = p1.next_batch()
    b2 = p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_host_sharding_disjoint():
    corpus = SyntheticCorpus()
    h0 = PackedLM(corpus, 1, 64, host_index=0, host_count=2)
    h1 = PackedLM(corpus, 1, 64, host_index=1, host_count=2)
    h0.next_batch(); h1.next_batch()
    # doc indices drawn by the two hosts never overlap
    assert h0.state.next_doc % 2 == 0
    assert h1.state.next_doc % 2 == 1
