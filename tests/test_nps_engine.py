"""NPS generation behavior + serving engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GlassConfig, NPSConfig, compute_global_prior
from repro.core.nps import nps_corpus, nps_generate_batch, teacher_forced_batch
from repro.models import ModelConfig, build_model
from repro.serve.engine import Engine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=48, n_heads=4,
                  n_kv_heads=2, head_dim=12, d_ff=96, vocab_size=101,
                  dtype="float32", remat="none")


def test_nps_deterministic_and_shaped():
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    npc = NPSConfig(n_seqs=6, seq_len=20, batch=3, bos_id=1)
    c1 = nps_corpus(m, p, jax.random.key(5), npc)
    c2 = nps_corpus(m, p, jax.random.key(5), npc)
    assert c1.shape == (6, 20)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert int(jnp.max(c1)) < CFG.vocab_size


def test_bigram_penalty_reduces_repeats():
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    def repeats(npc):
        toks = np.asarray(nps_generate_batch(m, p, jax.random.key(7), npc, batch=16))
        reps = 0
        for row in toks:
            seen = set()
            for a, b in zip(row[:-1], row[1:]):
                if (a, b) in seen:
                    reps += 1
                seen.add((a, b))
        return reps
    hot = NPSConfig(seq_len=24, hot_steps=24, bigram_penalty=12.0, top_k=5, hot_temp=1.0, temp=1.0)
    off = NPSConfig(seq_len=24, hot_steps=0, bigram_penalty=0.0, top_k=5, hot_temp=1.0, temp=1.0)
    assert repeats(hot) <= repeats(off)


def test_teacher_forced_batch_alignment():
    toks = jnp.arange(10)[None].astype(jnp.int32)
    b = teacher_forced_batch(toks, bos_id=1)
    assert b["tokens"][0, 0] == 1
    np.testing.assert_array_equal(np.asarray(b["tokens"][0, 1:]), np.arange(9))
    np.testing.assert_array_equal(np.asarray(b["labels"]), np.asarray(toks))


def test_engine_dense_vs_glass_runs():
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    npc = NPSConfig(n_seqs=4, seq_len=16, batch=4, bos_id=1)
    prior = compute_global_prior(m, p, jax.random.key(1), npc, "A")
    prompts = jax.random.randint(jax.random.key(2), (2, 8), 3, CFG.vocab_size)
    dense = Engine(m, p)
    res_d = dense.generate(prompts, max_new=6)
    for mode in ("compact", "masked"):
        g = Engine(m, p, glass=GlassConfig(density=0.5), global_prior=prior, glass_mode=mode)
        res_g = g.generate(prompts, max_new=6)
        assert res_g.tokens.shape == (2, 6)
    assert res_d.tokens.shape == (2, 6)


def test_engine_full_density_matches_dense():
    """GLASS at density 1.0 must reproduce dense generation exactly."""
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    npc = NPSConfig(n_seqs=4, seq_len=16, batch=4, bos_id=1)
    prior = compute_global_prior(m, p, jax.random.key(1), npc, "A")
    prompts = jax.random.randint(jax.random.key(2), (2, 8), 3, CFG.vocab_size)
    res_d = Engine(m, p).generate(prompts, max_new=5)
    res_g = Engine(m, p, glass=GlassConfig(density=1.0), global_prior=prior).generate(prompts, max_new=5)
    np.testing.assert_array_equal(res_d.tokens, res_g.tokens)
