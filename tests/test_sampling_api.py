"""Per-request generation API: SamplingParams + GlassParams + streaming
RequestOutput frontend.

The load-bearing property is *reproducibility by construction*: a sampled
token is a pure function of (request seed, generated position, logits) —
the counter-based PRNG — so a request's stream does not depend on what the
engine did around it.  The tests here assert that from three directions:

  * **schedule invariance** — a seeded stream served in a mixed batch
    (greedy + sampled + different GLASS densities + a speculating
    neighbor) is token-identical to serving the request alone;
  * **per-request density equivalence** — a compact-mode request at a
    lower density (down-projection rows zeroed outside its nested
    selection) matches a masked-mode engine configured at that density;
  * **early finish is leak-free** — EOS/stop detection inside the fused
    scan truncates the stream at the hit and returns every block to the
    pool mid-drain; abort releases resources from any lifecycle state.

State-churn determinism (sampled streams through swap/recompute/rollback,
with RNG-counter and KV-row assertions) lives next to the machinery it
stresses: tests/test_lifecycle_preemption.py and
tests/test_speculative_decode.py.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GlassConfig, GlassParams
from repro.models import ModelConfig, build_model
from repro.serve.engine import PagedEngine
from repro.serve.lifecycle import Lifecycle, ReqState
from repro.serve.sampling import (
    MAX_STOP_IDS,
    SamplingParams,
    min_p_filter_dynamic,
    sample_positional,
    top_k_filter_dynamic,
    top_p_filter_dynamic,
)
from repro.serve.scheduler import Request

pytestmark = pytest.mark.sampling

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="sa-dense", family="dense", **BASE)
SSM = ModelConfig(name="sa-ssm", family="ssm", rwkv_headdim=12, **BASE)


def _prior_for(cfg: ModelConfig):
    if cfg.family == "hybrid":
        return jnp.abs(jax.random.normal(jax.random.key(7), (cfg.d_ff,)))
    return jnp.abs(jax.random.normal(jax.random.key(7), (cfg.n_layers, cfg.d_ff)))


def _engine(cfg=DENSE, *, glass=None, prior=None, glass_mode="compact", **kw):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if glass is not None and prior is None:
        prior = _prior_for(cfg)
    eng = PagedEngine(model, params, max_slots=kw.pop("max_slots", 4),
                      max_len=kw.pop("max_len", 64),
                      block_size=8, chunk_tokens=kw.pop("chunk_tokens", 4),
                      glass=glass, global_prior=prior, glass_mode=glass_mode,
                      **kw)
    return model, params, prior, eng


def _prompt(seed=0, n=6):
    return np.random.RandomState(seed).randint(3, 101, size=n).astype(np.int32)


def _drain(eng):
    outs = {}
    guard = 0
    while eng._work_remaining():
        guard += 1
        assert guard < 600, "engine did not drain"
        for o in eng.step():
            if o.finished:
                outs[o.uid] = o
    return outs


# -- SamplingParams / sample_positional primitives ----------------------------


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="stop ids"):
        SamplingParams(eos_token_id=1, stop_token_ids=tuple(range(2, 2 + MAX_STOP_IDS)))
    # greedy special cases: no seed, explicit flag, zero temperature
    assert SamplingParams().is_greedy
    assert SamplingParams(seed=3, greedy=True).is_greedy
    assert SamplingParams(seed=3, temperature=0.0).is_greedy
    assert not SamplingParams(seed=3, temperature=0.8).is_greedy
    g = SamplingParams.make_greedy(eos_token_id=7, stop_token_ids=(9,))
    assert g.is_greedy and g.stop_set == (7, 9)
    # eos is deduplicated from the stop set, eos stays first
    assert SamplingParams(eos_token_id=5, stop_token_ids=(9, 5)).stop_set == (5, 9)


def test_glass_params_validation():
    with pytest.raises(ValueError, match="density"):
        GlassParams(density=0.0)
    with pytest.raises(ValueError, match="draft_ratio"):
        GlassParams(draft_ratio=1.5)
    with pytest.raises(ValueError, match="spec_k"):
        GlassParams(spec_k=-1)
    gp = GlassParams().resolve(GlassConfig(density=0.5, draft_ratio=0.5), 3)
    assert gp.density == 0.5 and gp.draft_ratio == 0.5 and gp.spec_k == 3
    gp = GlassParams(density=0.25, spec_k=0).resolve(
        GlassConfig(density=0.5, draft_ratio=0.5), 3)
    assert gp.density == 0.25 and gp.spec_k == 0
    assert GlassParams().resolve(None, 0).density is None


def test_sample_positional_counter_properties():
    """The counter-based draw is a pure function of (seed, pos, logits):
    identical inputs reproduce bit-identically (eager AND jitted), and the
    (seed, pos) pair really keys the stream."""
    lg = jnp.asarray(np.random.RandomState(0).randn(4, 64), jnp.float32)
    seeds = jnp.asarray([11, 11, 42, 42], jnp.int32)
    pos = jnp.asarray([0, 1, 0, 1], jnp.int32)
    t = jnp.full((4,), 1.0, jnp.float32)
    k = jnp.zeros((4,), jnp.int32)
    a = sample_positional(lg, seeds, pos, t, k)
    b = sample_positional(lg, seeds, pos, t, k)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(sample_positional)(lg, seeds, pos, t, k)), np.asarray(a)
    )
    # a row's draw depends only on ITS (seed, pos, logits) — not on the
    # batch around it (the schedule-invariance primitive)
    solo = sample_positional(lg[1:2], seeds[1:2], pos[1:2], t[1:2], k[1:2])
    assert int(solo[0]) == int(a[1])
    # across many positions, two seeds disagree somewhere (not a constant)
    row = jnp.tile(lg[0:1], (32, 1))
    ps = jnp.arange(32, dtype=jnp.int32)
    s1 = sample_positional(row, jnp.full((32,), 11, jnp.int32), ps,
                           jnp.ones((32,), jnp.float32), jnp.zeros((32,), jnp.int32))
    s2 = sample_positional(row, jnp.full((32,), 42, jnp.int32), ps,
                           jnp.ones((32,), jnp.float32), jnp.zeros((32,), jnp.int32))
    assert np.any(np.asarray(s1) != np.asarray(s2))
    assert len(set(np.asarray(s1).tolist())) > 1  # position really varies the draw


def test_dynamic_top_k_filter():
    lg = jnp.asarray([[3.0, 1.0, 2.0, 0.0], [3.0, 1.0, 2.0, 0.0]])
    out = np.asarray(top_k_filter_dynamic(lg, jnp.asarray([2, 0])))
    assert (out[0] > -1e29).sum() == 2 and out[0][1] < -1e29
    np.testing.assert_array_equal(out[1], np.asarray(lg[1]))  # k=0: no filter
    # top_k=1 sampling degenerates to argmax at any temperature
    g = sample_positional(lg, jnp.asarray([5, 6], jnp.int32),
                          jnp.asarray([0, 0], jnp.int32),
                          jnp.asarray([2.0, 2.0], jnp.float32),
                          jnp.asarray([1, 1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(g), [0, 0])


def test_sampling_params_top_p_min_p_validation():
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="min_p"):
        SamplingParams(min_p=-0.1)
    with pytest.raises(ValueError, match="min_p"):
        SamplingParams(min_p=1.5)
    # the no-op defaults stay greedy-compatible
    sp = SamplingParams(seed=1, temperature=0.8, top_p=0.9, min_p=0.05)
    assert not sp.is_greedy


def test_dynamic_top_p_filter():
    # softmax([3, 1, 2, 0]) ~= [.644, .087, .237, .032]; sorted-desc
    # cumulative-BEFORE-token: [0, .644, .881, .968]
    lg = jnp.asarray([[3.0, 1.0, 2.0, 0.0]] * 3)
    p = jnp.asarray([0.5, 0.7, 1.0], jnp.float32)
    out = np.asarray(top_p_filter_dynamic(lg, p))
    kept = (out > -1e29).sum(axis=-1)
    assert kept[0] == 1 and out[0][0] > -1e29  # nucleus = just the top token
    assert kept[1] == 2 and out[1][2] > -1e29  # .644 < .7 admits the runner-up
    np.testing.assert_array_equal(out[2], np.asarray(lg[2]))  # p=1: no filter
    # surviving logits pass through unchanged (the draw stays counter-exact)
    np.testing.assert_array_equal(out[1][[0, 2]], np.asarray(lg)[1][[0, 2]])
    # top_p small enough to isolate the mode degenerates to argmax at any
    # temperature — the nucleus analogue of the top_k=1 property
    g = sample_positional(lg, jnp.asarray([5, 6, 7], jnp.int32),
                          jnp.asarray([0, 0, 0], jnp.int32),
                          jnp.asarray([3.0, 3.0, 3.0], jnp.float32),
                          jnp.zeros((3,), jnp.int32),
                          top_p=jnp.asarray([0.1, 0.1, 0.1], jnp.float32))
    np.testing.assert_array_equal(np.asarray(g), [0, 0, 0])


def test_dynamic_min_p_filter():
    # keep tokens with prob >= mp * max-prob: mp=.3 -> {.644, .237}
    lg = jnp.asarray([[3.0, 1.0, 2.0, 0.0]] * 2)
    mp = jnp.asarray([0.3, 0.0], jnp.float32)
    out = np.asarray(min_p_filter_dynamic(lg, mp))
    assert (out[0] > -1e29).sum() == 2
    assert out[0][0] > -1e29 and out[0][2] > -1e29
    np.testing.assert_array_equal(out[1], np.asarray(lg[1]))  # mp=0: no filter
    # near-1 min-p isolates the mode -> argmax
    g = sample_positional(lg, jnp.asarray([5, 6], jnp.int32),
                          jnp.asarray([0, 0], jnp.int32),
                          jnp.asarray([2.0, 2.0], jnp.float32),
                          jnp.zeros((2,), jnp.int32),
                          min_p=jnp.asarray([0.99, 0.99], jnp.float32))
    np.testing.assert_array_equal(np.asarray(g), [0, 0])


def test_top_p_min_p_streams_reproducible_and_schedule_invariant():
    """Nucleus/min-p requests keep the counter-based contract: identical
    engines replay the stream bit-identically, the filters actually bite
    (the unfiltered stream diverges), and a neighbor in the batch does not
    perturb the draws."""
    sp = SamplingParams(temperature=1.0, seed=7, top_p=0.3, min_p=0.05)
    toks = []
    for _ in range(2):
        _, _, _, eng = _engine()
        u = eng.add_request(_prompt(50), 12, sampling=sp)
        toks.append(_drain(eng)[u].tokens)
    np.testing.assert_array_equal(toks[0], toks[1])
    _, _, _, base_eng = _engine()
    ub = base_eng.add_request(_prompt(50), 12,
                              sampling=SamplingParams(temperature=1.0, seed=7))
    base = _drain(base_eng)[ub].tokens
    assert np.any(base != toks[0])  # the filters changed some draw
    # schedule invariance next to a greedy neighbor
    _, _, _, mixed = _engine(max_slots=2)
    u0 = mixed.add_request(_prompt(50), 12, sampling=sp)
    u1 = mixed.add_request(_prompt(51), 12)
    outs = _drain(mixed)
    np.testing.assert_array_equal(outs[u0].tokens, toks[0])
    assert outs[u1].finish_reason == "length"


# -- model/launch layer: one key convention across all three entry points ----


def test_builders_share_the_positional_key_convention():
    """make_decode_step_sampled and Model.verify_steps(seeds=...) must draw
    the SAME position-keyed tokens as sample_positional itself — three
    entry points, one (seed, position, logits) convention.  Drift here
    would silently break the engine's draft/verify exactness contract."""
    from repro.launch.steps import make_decode_step_sampled

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    B = 2
    toks = jnp.asarray(np.random.RandomState(0).randint(3, 101, size=(B, 5)),
                       jnp.int32)
    _, cache, _ = model.prefill(params, {"tokens": toks}, 16)
    clen = jnp.full((B,), 5, jnp.int32)
    tok = jnp.asarray([[9], [11]], jnp.int32)
    seeds = jnp.asarray([77, 13], jnp.int32)
    pos = jnp.asarray([4, 7], jnp.int32)
    temp = jnp.asarray([0.9, 1.1], jnp.float32)
    topk = jnp.asarray([25, 0], jnp.int32)
    gmask = jnp.asarray([False, True])
    # ground truth: one decode step's logits through sample_positional
    lg, cache_ref = model.decode_step(params, tok, cache, clen)
    lg = lg[:, -1].astype(jnp.float32)
    want = np.where(np.asarray(gmask),
                    np.asarray(jnp.argmax(lg, axis=-1)),
                    np.asarray(sample_positional(lg, seeds, pos, temp, topk)))
    # launch-layer builder
    step = make_decode_step_sampled(model)
    _, cache2, _ = model.prefill(params, {"tokens": toks}, 16)
    nxt, _ = step(params, cache2, tok, clen, seeds, pos, temp, topk, gmask)
    np.testing.assert_array_equal(np.asarray(nxt)[:, 0], want)
    # model-layer multi-token verify: T sequential feeds, verdict j keyed
    # on pos0 + j — replicate by hand through decode_step
    feed = jnp.asarray(np.random.RandomState(1).randint(3, 101, size=(B, 3)),
                       jnp.int32)
    _, cache3, _ = model.prefill(params, {"tokens": toks}, 16)
    verdicts, _ = model.verify_steps(params, feed, cache3, clen, seeds=seeds,
                                     pos0=pos, temperature=temp, top_k=topk,
                                     greedy_mask=gmask)
    _, cache4, _ = model.prefill(params, {"tokens": toks}, 16)
    cl = clen
    for j in range(3):
        lgj, cache4 = model.decode_step(params, feed[:, j:j + 1], cache4, cl)
        lgj = lgj[:, -1].astype(jnp.float32)
        wj = np.where(np.asarray(gmask),
                      np.asarray(jnp.argmax(lgj, axis=-1)),
                      np.asarray(sample_positional(lgj, seeds, pos + j, temp, topk)))
        np.testing.assert_array_equal(np.asarray(verdicts[:, j]), wj, err_msg=f"j={j}")
        cl = cl + 1


# -- the acceptance-criteria mixed batch --------------------------------------


def _mixed_requests(eng):
    """greedy+speculative, seeded-sampled @ engine density, seeded-sampled
    @ half density, greedy @ half density — one add_request each."""
    uids = {}
    uids["spec"] = eng.add_request(_prompt(1), 12, glass=GlassParams(spec_k=2))
    uids["sampled"] = eng.add_request(
        _prompt(2), 12, sampling=SamplingParams(temperature=0.9, top_k=25, seed=77),
        glass=GlassParams(spec_k=0))
    uids["sampled_low"] = eng.add_request(
        _prompt(3), 12, sampling=SamplingParams(temperature=1.1, seed=13),
        glass=GlassParams(density=0.25, spec_k=0))
    uids["greedy_low"] = eng.add_request(
        _prompt(4), 12, glass=GlassParams(density=0.25, spec_k=0))
    return uids


def test_mixed_batch_one_tick_and_schedule_invariance():
    """ACCEPTANCE: a single PagedEngine tick serves greedy + seeded-sampled
    requests at two GLASS densities with one spec_k>0 request speculating —
    and every stream is token-identical to serving that request alone
    (counter-based sampling + per-slot masks make scheduling invisible)."""
    glass = GlassConfig(density=0.5, draft_ratio=0.5)
    _, _, prior, eng = _engine(glass=glass)
    uids = _mixed_requests(eng)
    mixed_tick = False
    outs = {}
    guard = 0
    while eng._work_remaining():
        guard += 1
        assert guard < 600
        run = eng.lc.in_state(ReqState.RUNNING)
        spec_live = any(e.gp.spec_k > 0 for e in run)
        plain_live = any(e.gp.spec_k == 0 for e in run)
        spec0 = eng.spec_ticks
        for o in eng.step():
            if o.finished:
                outs[o.uid] = o
        if spec_live and plain_live and eng.spec_ticks > spec0:
            mixed_tick = True  # a speculative round and plain decode shared a tick
    assert mixed_tick, "no tick interleaved a speculative round with plain decode"
    assert eng.spec_ticks > 0
    assert eng.pool.allocator.n_live == 0
    assert sorted(outs) == sorted(uids.values())
    for o in outs.values():
        assert o.finished and o.finish_reason == "length"
        assert o.tokens.shape == (12,)
    # schedule invariance: each request alone reproduces its mixed-batch
    # stream bit-for-bit (greedy AND seeded-sampled, both densities)
    specs = {
        "spec": dict(glass=GlassParams(spec_k=2)),
        "sampled": dict(sampling=SamplingParams(temperature=0.9, top_k=25, seed=77),
                        glass=GlassParams(spec_k=0)),
        "sampled_low": dict(sampling=SamplingParams(temperature=1.1, seed=13),
                            glass=GlassParams(density=0.25, spec_k=0)),
        "greedy_low": dict(glass=GlassParams(density=0.25, spec_k=0)),
    }
    prompts = {"spec": _prompt(1), "sampled": _prompt(2),
               "sampled_low": _prompt(3), "greedy_low": _prompt(4)}
    for name, kw in specs.items():
        _, _, _, solo = _engine(glass=glass, prior=prior)
        u = solo.add_request(prompts[name], 12, **kw)
        alone = _drain(solo)[u]
        np.testing.assert_array_equal(alone.tokens, outs[uids[name]].tokens,
                                      err_msg=name)


def test_seeded_stream_replays_identically():
    """Submitting the identical seeded request twice (fresh engines) gives
    bit-identical streams — and a different seed diverges somewhere."""
    tok = {}
    for seed in (123, 123, 321):
        _, _, _, eng = _engine()
        u = eng.add_request(_prompt(5), 16,
                            sampling=SamplingParams(temperature=1.0, seed=seed))
        tok.setdefault(seed, []).append(_drain(eng)[u].tokens)
    np.testing.assert_array_equal(tok[123][0], tok[123][1])
    assert np.any(tok[123][0] != tok[321][0])


# -- per-request GLASS density ------------------------------------------------


@pytest.mark.parametrize("mode", ["compact", "masked", "block_sparse"])
def test_per_request_density_matches_engine_at_that_density(mode):
    """A request at density 0.25 inside a density-0.5 engine (capacity
    tier) must produce the stream of an engine CONFIGURED at 0.25 — the
    compact path proves the down-projection zeroing is exact, the masked
    path the direct low-density mask, and the block_sparse path the
    per-(row, tile) contribution scales on the streaming kernel (blocks
    selected at the lower density keep scale 1, the rest scale 0)."""
    if mode == "block_sparse":
        glass_hi = GlassConfig(density=0.5, selection="block", block_size=32)
        glass_lo = GlassConfig(density=0.25, selection="block", block_size=32)
    else:
        glass_hi = GlassConfig(density=0.5)
        glass_lo = GlassConfig(density=0.25)
    _, _, prior, eng = _engine(glass=glass_hi, glass_mode=mode)
    u = eng.add_request(_prompt(6), 10, glass=GlassParams(density=0.25))
    got = _drain(eng)[u]
    _, _, _, ref = _engine(glass=glass_lo, prior=prior, glass_mode=mode)
    ur = ref.add_request(_prompt(6), 10)
    want = _drain(ref)[ur]
    np.testing.assert_array_equal(want.tokens, got.tokens)


def test_per_request_density_rwkv_masked():
    """The ssm family's per-request density (masked arena) agrees with an
    engine configured at that density."""
    _, _, prior, eng = _engine(SSM, glass=GlassConfig(density=0.5),
                               glass_mode="masked")
    u = eng.add_request(_prompt(7), 8, glass=GlassParams(density=0.25))
    got = _drain(eng)[u]
    _, _, _, ref = _engine(SSM, glass=GlassConfig(density=0.25), prior=prior,
                           glass_mode="masked")
    ur = ref.add_request(_prompt(7), 8)
    np.testing.assert_array_equal(_drain(ref)[ur].tokens, got.tokens)


def test_per_request_glass_validation():
    glass = GlassConfig(density=0.5, draft_ratio=0.5)
    _, _, _, eng = _engine(glass=glass)
    with pytest.raises(ValueError, match="exceeds the engine capacity"):
        eng.add_request(_prompt(), 4, glass=GlassParams(density=0.9))
    with pytest.raises(ValueError, match="draft capacity"):
        eng.add_request(_prompt(), 4, glass=GlassParams(draft_ratio=0.9, spec_k=2))
    # dense engine: per-request GLASS is meaningless
    _, _, _, dense = _engine()
    with pytest.raises(ValueError, match="engine-level GlassConfig"):
        dense.add_request(_prompt(), 4, glass=GlassParams(density=0.25))
    with pytest.raises(ValueError, match="draft tier"):
        dense.add_request(_prompt(), 4, glass=GlassParams(spec_k=2))
    # spec / draft_ratio against an engine without a draft arena
    _, _, _, nodraft = _engine(glass=GlassConfig(density=0.5))
    with pytest.raises(ValueError, match="draft tier"):
        nodraft.add_request(_prompt(), 4, glass=GlassParams(spec_k=2))
    with pytest.raises(ValueError, match="draft arena"):
        nodraft.add_request(_prompt(), 4, glass=GlassParams(draft_ratio=0.3))
    # the ignored-rng legacy knob warns instead of silently changing streams
    model = build_model(DENSE)
    with pytest.warns(DeprecationWarning, match="counter-based"):
        PagedEngine(model, model.init(jax.random.key(0)), max_slots=2,
                    max_len=32, block_size=8, rng=jax.random.key(3))
    # block_sparse: per-request densities feed the streaming kernel through
    # per-(row, tile) contribution scales — lower AND equal both admit
    bs = GlassConfig(density=0.5, selection="block", block_size=32)
    _, _, _, bse = _engine(glass=bs, glass_mode="block_sparse")
    bse.add_request(_prompt(), 4, glass=GlassParams(density=0.25))
    bse.add_request(_prompt(), 4, glass=GlassParams(density=0.5))
    with pytest.raises(ValueError, match="exceeds the engine capacity"):
        bse.add_request(_prompt(), 4, glass=GlassParams(density=0.9))


# -- early finish: EOS / stop tokens inside the scan --------------------------


def test_eos_early_finish_frees_blocks():
    """ACCEPTANCE: a request finishing on EOS mid-stream is truncated at
    the hit, reports finish_reason='eos', and its blocks are verifiably
    back in the pool — it never runs to max_new."""
    _, _, _, probe = _engine()
    up = probe.add_request(_prompt(8), 16)
    ref = _drain(probe)[up].tokens
    eos = int(ref[5])  # a token the greedy stream really emits mid-way
    first = int(np.nonzero(ref == eos)[0][0])
    _, _, _, eng = _engine()
    u = eng.add_request(_prompt(8), 16,
                        sampling=SamplingParams.make_greedy(eos_token_id=eos))
    out = _drain(eng)[u]
    assert out.finish_reason == "eos"
    assert out.tokens.shape[0] == first + 1 < 16
    np.testing.assert_array_equal(out.tokens, ref[: first + 1])
    assert eng.pool.allocator.n_live == 0  # every block back in the pool
    assert eng.lc.counts[("running", "finished")] >= 1
    # stop_token_ids give finish_reason='stop' for non-eos ids
    _, _, _, eng2 = _engine()
    u2 = eng2.add_request(_prompt(8), 16,
                          sampling=SamplingParams.make_greedy(stop_token_ids=(eos,)))
    out2 = _drain(eng2)[u2]
    assert out2.finish_reason == "stop"
    np.testing.assert_array_equal(out2.tokens, out.tokens)


def test_eos_mid_fused_chunk_frees_midtick():
    """EOS inside a fused H>1 chunk finishes the request in that same tick
    (blocks freed mid-tick), while a neighbor keeps decoding to length."""
    _, _, _, probe = _engine(max_slots=2)
    up = probe.add_request(_prompt(9), 20)
    ref = _drain(probe)[up].tokens
    eos = int(ref[7])
    first = int(np.nonzero(ref == eos)[0][0])
    _, _, _, eng = _engine(max_slots=2, decode_chunk=8)
    u0 = eng.add_request(_prompt(9), 20,
                         sampling=SamplingParams.make_greedy(eos_token_id=eos))
    u1 = eng.add_request(_prompt(10), 20)
    freed_before_drain = False
    outs = {}
    guard = 0
    while eng._work_remaining():
        guard += 1
        assert guard < 400
        for o in eng.step():
            if o.finished:
                outs[o.uid] = o
        if u0 in outs and eng.lc.entries.get(u1) is not None:
            freed_before_drain = True  # u0's blocks returned while u1 lives
    assert freed_before_drain
    assert outs[u0].finish_reason == "eos"
    assert outs[u0].tokens.shape[0] == first + 1
    assert outs[u1].finish_reason == "length" and outs[u1].tokens.shape[0] == 20
    np.testing.assert_array_equal(outs[u0].tokens, ref[: first + 1])
    assert eng.pool.allocator.n_live == 0


def test_eos_through_speculative_accept():
    """A speculating request whose ACCEPTED tokens contain the eos: the
    stream truncates at the eos, the speculation's blocks roll back/free,
    and the tokens match the non-speculative eos stream."""
    glass = GlassConfig(density=0.5, draft_ratio=0.5)
    _, _, prior, probe = _engine(glass=glass)
    up = probe.add_request(_prompt(11), 16)
    ref = _drain(probe)[up].tokens
    eos = int(ref[6])
    first = int(np.nonzero(ref == eos)[0][0])
    _, _, _, eng = _engine(glass=glass, prior=prior, spec_k=3)
    u = eng.add_request(_prompt(11), 16,
                        sampling=SamplingParams.make_greedy(eos_token_id=eos))
    out = _drain(eng)[u]
    assert eng.spec_ticks > 0
    assert out.finish_reason == "eos"
    np.testing.assert_array_equal(out.tokens, ref[: first + 1])
    assert eng.pool.allocator.n_live == 0


# -- streaming deltas ---------------------------------------------------------


def test_streaming_deltas_concatenate_to_final_stream():
    _, _, _, eng = _engine(max_slots=2)
    u0 = eng.add_request(_prompt(12), 9)
    u1 = eng.add_request(_prompt(13), 13,
                         sampling=SamplingParams(temperature=0.8, seed=5))
    deltas = {u0: [], u1: []}
    final = {}
    guard = 0
    while eng._work_remaining():
        guard += 1
        assert guard < 300
        for o in eng.step():
            deltas[o.uid].append(np.asarray(o.new_tokens))
            if o.finished:
                final[o.uid] = o
            else:
                assert o.finish_reason is None and o.finished_step == -1
    for u in (u0, u1):
        got = np.concatenate([d for d in deltas[u] if d.size])
        np.testing.assert_array_equal(got, final[u].tokens)
        assert all(d.size > 0 for d in deltas[u][:-1] if d is not deltas[u][-1]) or True
    assert final[u0].tokens.shape == (9,) and final[u1].tokens.shape == (13,)
    assert final[u0].finish_reason == "length"


# -- abort --------------------------------------------------------------------


def test_abort_releases_resources_from_every_state():
    _, _, _, eng = _engine(max_slots=2)
    # queued (not yet arrived): removed without ever holding resources
    uq = eng.add_request(_prompt(14), 8, arrival=10_000)
    out = eng.abort(uq)
    assert out.finished and out.finish_reason == "aborted"
    assert out.tokens.size == 0 and len(eng.scheduler) == 0
    assert eng.lc.counts[("waiting", "finished")] == 1
    # RUNNING: slot + blocks released, partial tokens returned
    ur = eng.add_request(_prompt(15), 12)
    guard = 0
    while True:
        guard += 1
        assert guard < 100
        eng.step()
        e = eng.lc.entries.get(ur)
        if e is not None and e.state is ReqState.RUNNING and len(e.outputs) >= 2:
            break
    n = len(e.outputs)
    out = eng.abort(ur)
    assert out.finish_reason == "aborted" and out.tokens.shape[0] == n
    assert eng.pool.allocator.n_live == 0 and not eng.pool.active.any()
    assert eng.lc.counts[("running", "finished")] == 1
    # unknown / already finished uids: None
    assert eng.abort(ur) is None
    assert eng.abort(424242) is None
    # PREEMPTED_SWAPPED: the host store is dropped, nothing re-allocates
    us = eng.add_request(_prompt(16), 12)
    guard = 0
    while True:
        guard += 1
        assert guard < 100
        eng.step()
        e = eng.lc.entries.get(us)
        if e is not None and e.state is ReqState.RUNNING and len(e.outputs) >= 2:
            break
    eng._preempt(e, "swap")
    assert e.swap is not None
    out = eng.abort(us)
    assert out.finish_reason == "aborted" and e.swap is None
    assert eng.pool.allocator.n_live == 0
    # PREEMPTED_RECOMPUTE: the queued replay is cancelled
    uc = eng.add_request(_prompt(17), 12)
    guard = 0
    while True:
        guard += 1
        assert guard < 100
        eng.step()
        e = eng.lc.entries.get(uc)
        if e is not None and e.state is ReqState.RUNNING and len(e.outputs) >= 2:
            break
    eng._preempt(e, "recompute")
    assert len(eng.scheduler) == 1
    out = eng.abort(uc)
    assert out.finish_reason == "aborted" and len(eng.scheduler) == 0
    assert eng.pool.allocator.n_live == 0
    assert not eng._work_remaining()


def test_abort_during_drain_keeps_neighbors_exact():
    """Aborting one request mid-flight must not perturb a neighbor's
    stream (slot isolation + schedule-invariant sampling)."""
    _, _, _, eng = _engine(max_slots=2)
    u0 = eng.add_request(_prompt(18), 14,
                         sampling=SamplingParams(temperature=1.0, seed=99))
    u1 = eng.add_request(_prompt(19), 14)
    outs = {}
    for _ in range(6):
        for o in eng.step():
            if o.finished:
                outs[o.uid] = o
    eng.abort(u1)
    outs.update(_drain(eng))
    _, _, _, solo = _engine()
    us = solo.add_request(_prompt(18), 14,
                          sampling=SamplingParams(temperature=1.0, seed=99))
    np.testing.assert_array_equal(_drain(solo)[us].tokens, outs[u0].tokens)


# -- lifecycle: the FINISHED-via-stop transitions -----------------------------


def test_lifecycle_early_finish_transitions():
    lc = Lifecycle()
    e = lc.add(Request(uid=0, prompt=np.zeros(4, np.int32), max_new=2))
    lc.to(e, ReqState.FINISHED)  # abort straight from WAITING
    e = lc.add(Request(uid=1, prompt=np.zeros(4, np.int32), max_new=2))
    lc.to(e, ReqState.PREFILLING)
    lc.to(e, ReqState.FINISHED)  # abort mid-prefill
    e = lc.add(Request(uid=2, prompt=np.zeros(4, np.int32), max_new=2))
    lc.to(e, ReqState.PREFILLING)
    lc.to(e, ReqState.RUNNING)
    lc.to(e, ReqState.PREEMPTED_SWAPPED)
    lc.to(e, ReqState.FINISHED)  # abort while swapped out
    e = lc.add(Request(uid=3, prompt=np.zeros(4, np.int32), max_new=2))
    lc.to(e, ReqState.PREFILLING)
    lc.to(e, ReqState.RUNNING)
    lc.to(e, ReqState.SPECULATING)
    with pytest.raises(ValueError, match="illegal transition"):
        lc.to(e, ReqState.FINISHED)  # pending drafts must roll back first
    lc.to(e, ReqState.RUNNING)
    lc.to(e, ReqState.FINISHED)


# -- legacy shim --------------------------------------------------------------


def test_legacy_request_run_shim_warns_and_matches():
    """Satellite: Request + run(requests) keep working (greedy, engine
    GLASS defaults) behind a DeprecationWarning, token-identical to the
    first-class frontend."""
    glass = GlassConfig(density=0.5)
    _, _, prior, legacy = _engine(glass=glass)
    reqs = [Request(uid=i, prompt=_prompt(20 + i), max_new=8) for i in range(3)]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        done = legacy.run(reqs)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    _, _, _, fresh = _engine(glass=glass, prior=prior)
    for i in range(3):
        u = fresh.add_request(_prompt(20 + i), 8)
        assert u == i  # auto-uid allocation is sequential
    outs = _drain(fresh)
    for i in range(3):
        np.testing.assert_array_equal(done[i].tokens, outs[i].tokens)
        # legacy entries resolved to the engine-default greedy policy
        assert done[i].finish_reason == "length"


def test_auto_uid_never_aliases_finished_requests():
    """Regression: an auto-assigned uid must skip uids already used by
    finished explicit-uid requests — uid-keyed consumers would silently
    conflate the two streams."""
    _, _, _, eng = _engine()
    eng.add_request(_prompt(40), 2, uid=0)
    _drain(eng)
    assert 0 not in eng.lc.entries  # finished entries are pruned
    u = eng.add_request(_prompt(41), 2)
    assert u != 0
    # explicit reuse of a finished uid stays allowed (warmup/measured waves)
    assert eng.add_request(_prompt(42), 2, uid=0) == 0
    outs = _drain(eng)
    assert sorted(outs) == [0, u]


def test_submit_does_not_mutate_callers_request():
    """Regression: resolving per-request policy must not write the
    engine's defaults back into the caller's Request — the same object
    may be re-served through a differently-configured engine."""
    _, _, _, sampled_eng = _engine(temperature=0.9, top_k=10)
    r = Request(uid=0, prompt=_prompt(43), max_new=6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        done_sampled = sampled_eng.run([r])
    assert r.sampling is None and r.glass is None  # untouched
    _, _, _, greedy_eng = _engine()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        done_greedy = greedy_eng.run([r])
    # the greedy engine applied ITS defaults, not the sampled engine's
    _, _, _, ref = _engine()
    u = ref.add_request(_prompt(43), 6)
    np.testing.assert_array_equal(_drain(ref)[u].tokens, done_greedy[0].tokens)


def test_legacy_engine_temperature_maps_to_seeded_requests():
    """A legacy engine-global temperature serves per-request counter-based
    streams: deterministic across identical engines, divergent across
    uids."""
    outs = []
    for _ in range(2):
        _, _, _, eng = _engine(temperature=0.9, top_k=25)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            done = eng.run([Request(uid=i, prompt=_prompt(30), max_new=10)
                            for i in range(2)])
        outs.append(done)
    np.testing.assert_array_equal(outs[0][0].tokens, outs[1][0].tokens)
    np.testing.assert_array_equal(outs[0][1].tokens, outs[1][1].tokens)
    # same prompt, different uid-derived seeds -> different streams
    assert np.any(outs[0][0].tokens != outs[0][1].tokens)
