"""Test session config.

NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
device.  Multi-device tests spawn subprocesses (tests/helpers.py) that set
--xla_force_host_platform_device_count before jax initializes.
"""
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # for tests.helpers / benchmarks.* imports

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "speculative: self-speculative decode suite (tiered GLASS draft/verify "
        "+ state-invariant rollback checks); CI runs it as its own lane under "
        "SPEC_GLASS_MODE=fused and SPEC_GLASS_MODE=block_sparse",
    )
    config.addinivalue_line(
        "markers",
        "kernels: fused paged-attention kernel suite (kernel vs gather "
        "reference, T>1 parallel-verify bit-equality, pow2 bucket invariance, "
        "compiled-program churn); CI runs it as its own lane, excluded from "
        "tier-1",
    )
    config.addinivalue_line(
        "markers",
        "sampling: per-request generation API suite (SamplingParams counter-"
        "based PRNG, GlassParams densities, streaming RequestOutput, abort, "
        "EOS early finish); CI runs it as its own lane",
    )
    config.addinivalue_line(
        "markers",
        "prefix_cache: shared-prefix invariant suite (copy-on-write block "
        "tables, refcounted prefix cache, bit-identical warm-vs-cold "
        "prefill); CI runs it as its own lane under PREFIX_GLASS_MODE=fused "
        "and PREFIX_GLASS_MODE=block_sparse",
    )
    config.addinivalue_line(
        "markers",
        "cluster: replica-sharded serving suite (ClusterEngine global-queue "
        "dispatch, bit-identical cross-replica migration, swap-store cap, "
        "per-replica device placement); CI runs it as its own lane with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8, excluded from "
        "tier-1",
    )


# ATTN_MODE=paged_pallas reruns the whole serving corpus through the fused
# paged-attention kernel: every PagedEngine a test builds (unless it passes
# attn_mode itself) picks the mode up here.  Pure-recurrent families have no
# attention block table to fuse over and keep the gather default.
_ATTN_MODE = os.environ.get("ATTN_MODE", "gather")
if _ATTN_MODE != "gather":
    from repro.serve.engine import PagedEngine as _PagedEngine

    _orig_init = _PagedEngine.__init__

    def _attn_mode_init(self, model, params, *args, **kwargs):
        if "attn_mode" not in kwargs and getattr(model.cfg, "family", "") != "ssm":
            kwargs["attn_mode"] = _ATTN_MODE
        _orig_init(self, model, params, *args, **kwargs)

    _PagedEngine.__init__ = _attn_mode_init


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
