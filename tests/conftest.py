"""Test session config.

NOTE: no XLA_FLAGS here by design — smoke tests and benches must see ONE
device.  Multi-device tests spawn subprocesses (tests/helpers.py) that set
--xla_force_host_platform_device_count before jax initializes.
"""
import os
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # for tests.helpers / benchmarks.* imports

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "speculative: self-speculative decode suite (tiered GLASS draft/verify "
        "+ state-invariant rollback checks); CI runs it as its own lane under "
        "SPEC_GLASS_MODE=fused and SPEC_GLASS_MODE=block_sparse",
    )
    config.addinivalue_line(
        "markers",
        "sampling: per-request generation API suite (SamplingParams counter-"
        "based PRNG, GlassParams densities, streaming RequestOutput, abort, "
        "EOS early finish); CI runs it as its own lane",
    )
    config.addinivalue_line(
        "markers",
        "prefix_cache: shared-prefix invariant suite (copy-on-write block "
        "tables, refcounted prefix cache, bit-identical warm-vs-cold "
        "prefill); CI runs it as its own lane under PREFIX_GLASS_MODE=fused "
        "and PREFIX_GLASS_MODE=block_sparse",
    )


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
