"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, get_config, tiny_variant
from repro.models import build_model
from repro.train.optim import OptConfig, adamw_update, init_opt_state


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_MODELS)
def test_arch_smoke(arch):
    cfg = tiny_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(jax.random.key(2), (B, S, cfg.d_model), jnp.float32)
    logits = model.logits(p := params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one train step
    opt = init_opt_state(params)
    (loss, metrics), grads = jax.value_and_grad(
        lambda pp: model.loss(pp, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss)
    params2, opt2, om = adamw_update(params, grads, opt, OptConfig(lr=1e-3))
    loss2, _ = model.loss(params2, batch)
    assert jnp.isfinite(loss2)
    # one decode step off a prefill
    logits_p, cache, stats = model.prefill(params, batch, max_len=S + 4)
    lg, _ = model.decode_step(params, toks[:, :1], cache, jnp.int32(S))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))
    assert stats is not None
