"""End-to-end GLASS: priors -> fusion -> masks -> compaction -> decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GlassConfig, NPSConfig, build_masks, compact_params, compute_global_prior
from repro.core.importance import finalize
from repro.core.oracle import jaccard_vs_oracle, oracle_masks
from repro.models import ModelConfig, build_model

CFG = ModelConfig(name="t", family="dense", n_layers=3, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=131,
                  dtype="float32", remat="none")


@pytest.fixture(scope="module")
def setup():
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    npc = NPSConfig(n_seqs=8, seq_len=24, batch=8, bos_id=1)
    priorA = compute_global_prior(m, p, jax.random.key(1), npc, "A")
    priorI = compute_global_prior(m, p, jax.random.key(1), npc, "I")
    toks = jax.random.randint(jax.random.key(2), (2, 12), 0, 131)
    logits, cache, local = m.prefill(p, {"tokens": toks}, max_len=32)
    return m, p, priorA, priorI, toks, cache, local


def test_priors_finite_and_distinct(setup):
    m, p, priorA, priorI, *_ = setup
    assert priorA.shape == (3, 128) and priorI.shape == (3, 128)
    assert bool(jnp.all(jnp.isfinite(priorA))) and bool(jnp.all(jnp.isfinite(priorI)))
    # A and I are different signals (not identical rankings)
    ra = jnp.argsort(priorA, axis=-1)
    ri = jnp.argsort(priorI, axis=-1)
    assert not bool(jnp.all(ra == ri))


def test_masked_equals_compact_decode(setup):
    m, p, priorA, _, toks, cache, local = setup
    masks = build_masks(local, priorA, GlassConfig(density=0.5, lam=0.5))
    comp = compact_params(m, p, masks.idx)
    lg_m, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), ffn_masks=masks.mask)
    lg_c, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), compact_layers=comp)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_c), atol=1e-5)


def test_density_controls_kept_fraction(setup):
    m, p, priorA, _, toks, cache, local = setup
    for density in (0.25, 0.5, 0.75):
        ms = build_masks(local, priorA, GlassConfig(density=density))
        assert float(jnp.mean(ms.mask)) == pytest.approx(density, abs=1e-6)


def test_fused_beats_or_matches_singles_on_oracle(setup):
    """Directional check of paper Tab. 5: fused Jaccard >= min(single signals)."""
    m, p, priorA, _, toks, cache, local = setup
    full = jnp.concatenate([toks, jax.random.randint(jax.random.key(3), (2, 20), 0, 131)], 1)
    _, orc_mask = oracle_masks(m, p, full, prompt_len=12, density=0.5)
    scores = {}
    for lam, name in [(0.0, "local"), (1.0, "global"), (0.5, "fused")]:
        ms = build_masks(local, priorA, GlassConfig(density=0.5, lam=lam))
        scores[name] = float(jaccard_vs_oracle(ms.mask, orc_mask)["mean"])
    assert scores["fused"] >= min(scores["local"], scores["global"]) - 1e-6


def test_moe_per_expert_masks():
    cfg = CFG.replace(family="moe", n_experts=4, n_experts_per_tok=2, moe_strategy="dense")
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 131)
    _, cache, local = m.prefill(p, {"tokens": toks}, max_len=16)
    prior = jnp.abs(jax.random.normal(jax.random.key(4), (3, 4, 128)))
    ms = build_masks(local, prior, GlassConfig(density=0.5))
    assert ms.mask.shape == (3, 4, 128)
    comp = compact_params(m, p, ms.idx)
    assert comp["w_up"].shape == (3, 4, 64, 64)
    lg_m, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), ffn_masks=ms.mask)
    lg_c, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), compact_layers=comp)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_c), atol=1e-5)


def test_rwkv_compact_decode():
    cfg = CFG.replace(family="ssm", rwkv_headdim=16)
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 131)
    _, cache, local = m.prefill(p, {"tokens": toks}, max_len=16)
    prior = jnp.abs(jax.random.normal(jax.random.key(4), (3, 128)))
    ms = build_masks(local, prior, GlassConfig(density=0.5))
    comp = compact_params(m, p, ms.idx)
    lg_m, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), ffn_masks=ms.mask)
    lg_c, _ = m.decode_step(p, toks[:, :1], cache, jnp.int32(12), compact_layers=comp)
    np.testing.assert_allclose(np.asarray(lg_m), np.asarray(lg_c), atol=1e-5)


def test_impact_probe_matches_ablation():
    """First-order check: |h * dL/dh| from the gain probe approximates the
    actual loss change from ablating a unit (Taylor, Eq. 5)."""
    m = build_model(CFG)
    p = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, 131)
    batch = {"tokens": toks, "labels": toks}
    probes = m.probe_zeros((1, 8))
    g = jax.grad(lambda pr: m.loss_with_probes(p, pr, batch))(probes)  # (L,B,S,m)
    imp = jnp.sum(jnp.abs(g), axis=(1, 2))  # (L, m)
    # ablate the single most impactful unit vs the least impactful
    L, m_w = imp.shape
    lay = 1
    j_hi = int(jnp.argmax(imp[lay]))
    j_lo = int(jnp.argmin(imp[lay]))
    base, _ = m.loss(p, batch)

    def ablate(j):
        mask = jnp.ones((L, m_w)).at[lay, j].set(0.0)
        from repro.models import transformer
        logits, _, _, _ = transformer.forward(p, batch["tokens"], CFG, ffn_masks=mask)
        loss, _ = transformer.cross_entropy(logits, batch["labels"])
        return abs(float(loss - base))

    assert ablate(j_hi) >= ablate(j_lo)
