"""Prefix caching with copy-on-write block tables: the shared-prefix
invariant suite.

A cache-hit ("warm") prefill must be indistinguishable from a cold one —
not approximately, BIT-identically — because the cached entries carry the
exact artifacts an uncached prefill would have produced at the fork point:
whole KV blocks (immutable after registration; copy-on-write tables never
write shared blocks), the running GLASS stat left-fold at a block+chunk
aligned boundary, and the recurrent-state rows (rwkv6 / hybrid) at the
same position.  The suite enforces that across all four model families:

  * warm prefill reproduces the cold engine's fused GLASS mask rows, its
    gathered logical KV rows, its recurrent-state rows, and its greedy
    token stream, all bit-exact (np equality, not allclose);
  * concurrent requests share ONE physical copy of a common prefix
    (refcount 2 on the shared blocks, disjoint private tails);
  * the invariants survive swap/recompute preemption, speculative
    rollback (which must refuse to un-scatter a shared block), and
    mid-prefill abort while holding shared blocks;
  * a drained pool leaks nothing: every cache-indexed block sits at
    refcount 0, and evicting the index returns the allocator to its
    initial all-free state.

The CI lane runs this module twice: ``PREFIX_GLASS_MODE=fused`` (per-slot
fused masks / compact weights) and ``PREFIX_GLASS_MODE=block_sparse`` (the
dense family rerouted through block selection + the pallas block-sparse
decode kernel).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.engine import Engine, PagedEngine
from repro.serve.kv_pool import BlockPool, PrefixCache
from repro.serve.lifecycle import PreemptionConfig, ReqState
from repro.serve.scheduler import Request

pytestmark = pytest.mark.prefix_cache

PREFIX_LANE = os.environ.get("PREFIX_GLASS_MODE", "fused")  # fused | block_sparse

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="pc-dense", family="dense", **BASE)
MOE = ModelConfig(name="pc-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="pc-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="pc-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})

FAMILIES = {
    "dense": (DENSE, "compact"),
    "moe": (MOE, "masked"),
    "rwkv6": (SSM, "masked"),
    "hybrid": (HYBRID, "compact"),
}

# block_size == chunk_tokens == 4: every block boundary is chunk-aligned,
# so every full cached block is a legal resume point
BS = 4
CT = 4


def _family_setup(family):
    cfg, mode = FAMILIES[family]
    sel, bsz = "neuron", 128
    if PREFIX_LANE == "block_sparse" and cfg.family == "dense":
        mode, sel, bsz = "block_sparse", "block", 32
    return cfg, mode, sel, bsz


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _engine(family, *, prefix_cache, max_slots=2, num_blocks=None,
            preemption=None, spec_k=0, draft_ratio=None, max_len=32,
            decode_chunk=8):
    cfg, mode, sel, bsz = _family_setup(family)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    glass = GlassConfig(density=0.5, selection=sel, block_size=bsz,
                        draft_ratio=draft_ratio)
    eng = PagedEngine(model, params, max_slots=max_slots, max_len=max_len,
                      block_size=BS, num_blocks=num_blocks, chunk_tokens=CT,
                      glass=glass, global_prior=_prior_for(cfg),
                      glass_mode=mode, preemption=preemption, spec_k=spec_k,
                      decode_chunk=decode_chunk, prefix_cache=prefix_cache)
    ref = Engine(model, params, glass=glass, global_prior=_prior_for(cfg),
                 glass_mode=mode)
    return eng, ref


def _prompt(n, seed=0, lo=3):
    return np.random.RandomState(seed).randint(lo, 101, size=n).astype(np.int32)


def _step_until(eng, uid, state, min_outputs=0, limit=400):
    done = []
    for _ in range(limit):
        done += eng.step()
        e = eng.lc.entries.get(uid)
        if e is not None and e.state is state and len(e.outputs) >= min_outputs:
            return e, done
    raise AssertionError(f"uid {uid} never reached {state}")


def _logical_kv_rows(pool, slot, nrows):
    """Host copy of the slot's first ``nrows`` LOGICAL KV rows, gathered
    through its block table — physical block ids cancel out, so two pools
    agree here iff the row contents agree."""
    if not pool.has_paged:
        return []
    bs = pool.block_size
    ids = [int(pool.block_table[slot, r // bs]) for r in range(nrows)]
    offs = [r % bs for r in range(nrows)]
    out = []
    for leaf, ax, pg in zip(
        jax.tree.leaves(pool.cache), jax.tree.leaves(pool.axes),
        jax.tree.leaves(pool.paged),
    ):
        if not pg:
            continue
        a = np.asarray(leaf)
        out.append(np.stack([
            np.take(np.take(a, [ids[i]], axis=ax), [offs[i]], axis=ax + 1)
            for i in range(nrows)
        ]))
    return out


def _state_rows(pool, slot):
    """Host copy of the slot's recurrent-state rows (non-paged leaves)."""
    out = []
    for leaf, ax, pg in zip(
        jax.tree.leaves(pool.cache), jax.tree.leaves(pool.axes),
        jax.tree.leaves(pool.paged),
    ):
        if not pg:
            out.append(np.take(np.asarray(leaf), [slot], axis=ax))
    return out


def _glass_rows(eng, slot):
    gs = eng.glass_slots
    if gs is None or gs.arena is None:
        return None
    ax = gs.slot_axis
    return [np.take(np.asarray(a), [slot], axis=ax) for a in jax.tree.leaves(gs.arena)]


def _assert_drained_clean(eng):
    """Leak regression: after a drain the pool's only live blocks are the
    cache-retained ones (all refcount 0), and evicting the whole index
    returns the allocator to its initial all-free state."""
    pool = eng.pool
    assert not pool.active.any()
    assert (pool.lengths == 0).all()
    pc = pool.prefix_cache
    alloc = pool.allocator
    cached = [e.block for e in pc.entries.values() if e.block >= 0]
    if alloc is None:
        # pure-state pool: entries are block-less snapshots, nothing to leak
        assert not cached
        return
    assert len(cached) == len(set(cached))  # one entry per physical block
    for b in cached:
        assert alloc.refcount(b) == 0  # index holds only refcount-0 entries
    assert alloc.n_live == len(cached)
    # the incremental reclaimable counter agrees with a full index scan
    assert pool.n_reclaimable_blocks == sum(
        1 for b in pc.by_block if alloc.refcount(b) == 0
    ) == len(cached)
    pc.evict_for(alloc, alloc.n_live + 1)
    assert len([e for e in pc.entries.values() if e.block >= 0]) == 0
    assert alloc.n_live == 0
    assert alloc.n_free == pool.num_blocks - 1
    assert pool.n_reclaimable_blocks == 0


# -- warm-vs-cold bit-identity across families --------------------------------


@pytest.mark.parametrize("family", list(FAMILIES), ids=list(FAMILIES))
def test_warm_prefill_bit_identical(family):
    """A cache-hit prefill reproduces the cold engine's fused GLASS mask,
    logical KV rows, recurrent-state rows, and greedy stream bit-exactly."""
    shared = _prompt(12, seed=3)  # 3 full blocks, chunk-aligned fork
    tail = _prompt(3, seed=4)
    prompt2 = np.concatenate([shared, tail])

    warm, ref = _engine(family, prefix_cache=True)
    cold, _ = _engine(family, prefix_cache=False)

    # populate: request 1 writes the shared prefix into the cache
    done1 = warm.run([Request(uid=1, prompt=shared, max_new=3)])
    assert len(warm.pool.prefix_cache.entries) >= 2  # full blocks registered
    baseline_inserts = warm.pool.prefix_cache.inserts

    warm.submit(Request(uid=2, prompt=prompt2, max_new=4))
    cold.submit(Request(uid=2, prompt=prompt2, max_new=4))
    ew, dw = _step_until(warm, 2, ReqState.RUNNING, min_outputs=1)
    ec, dc = _step_until(cold, 2, ReqState.RUNNING, min_outputs=1)

    # the admission actually hit: prefill resumed at the fork
    pc = warm.pool.prefix_cache
    assert pc.hits >= 1 and pc.tokens_saved >= 8
    assert ew.cached_rows >= 8 and ew.cached_rows % CT == 0

    # bit-identity at the finalize instant
    assert len(ew.outputs) == len(ec.outputs)
    assert ew.outputs == ec.outputs
    gw, gc = _glass_rows(warm, ew.slot), _glass_rows(cold, ec.slot)
    assert (gw is None) == (gc is None)
    if gw is not None:
        for a, b in zip(gw, gc):
            np.testing.assert_array_equal(a, b)
    if warm._mode == "block_sparse":
        assert ew.glass_key == ec.glass_key
    for a, b in zip(
        _logical_kv_rows(warm.pool, ew.slot, len(prompt2)),
        _logical_kv_rows(cold.pool, ec.slot, len(prompt2)),
    ):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(_state_rows(warm.pool, ew.slot), _state_rows(cold.pool, ec.slot)):
        np.testing.assert_array_equal(a, b)

    # full greedy streams: warm == cold == single-request reference
    done_w = {o.uid: o for o in dw if o.finished}
    done_w.update(warm.run())
    done_c = {o.uid: o for o in dc if o.finished}
    done_c.update(cold.run())
    np.testing.assert_array_equal(done_w[2].tokens, done_c[2].tokens)
    want = ref.generate(jnp.asarray(prompt2)[None], 4).tokens[0]
    np.testing.assert_array_equal(want, done_w[2].tokens)
    want1 = ref.generate(jnp.asarray(shared)[None], 3).tokens[0]
    np.testing.assert_array_equal(want1, done1[1].tokens)
    # dedup: the warm request re-registered nothing for the shared chain
    assert pc.inserts <= baseline_inserts + 1  # at most its private tail
    _assert_drained_clean(warm)


@pytest.mark.parametrize("family", ["dense", "rwkv6"], ids=["dense", "rwkv6"])
def test_concurrent_requests_share_one_physical_prefix(family):
    """Two live requests over a common prefix hold the SAME physical
    blocks (refcount 2) — copy-on-write, not copy — and their private
    tails stay disjoint.  Streams still match single-request serving."""
    shared = _prompt(8, seed=5)
    p1 = np.concatenate([shared, _prompt(3, seed=6)])
    p2 = np.concatenate([shared, _prompt(3, seed=7)])
    eng, ref = _engine(family, prefix_cache=True)
    eng.submit(Request(uid=1, prompt=p1, max_new=4, arrival=0))
    # arrives after request 1 has prefilled the shared blocks
    eng.submit(Request(uid=2, prompt=p2, max_new=4, arrival=3))
    # the 3-token private tail warm-prefills in ONE chunk, so PREFILLING is
    # not observable between steps — catch uid 2 at its first decode instead
    e2, early = _step_until(eng, 2, ReqState.RUNNING, min_outputs=1)
    e1 = eng.lc.entries[1]
    assert e1.slot >= 0  # both live: sharing is observable right now
    if eng.pool.has_paged:
        assert e2.cached_rows == 8  # hit on 2 full blocks
        nsh = 8 // BS
        t1 = list(eng.pool.block_table[e1.slot, :nsh])
        t2 = list(eng.pool.block_table[e2.slot, :nsh])
        assert t1 == t2  # one physical copy
        for b in t1:
            assert eng.pool.allocator.refcount(b) == 2
            assert b in eng.pool.prefix_cache.by_block
        priv1 = set(eng.pool._held[e1.slot][nsh:])
        priv2 = set(eng.pool._held[e2.slot][nsh:])
        assert not (priv1 & priv2)  # tails never shared
    done = {o.uid: o for o in early if o.finished}
    done.update(eng.run())
    for uid, p in [(1, p1), (2, p2)]:
        want = ref.generate(jnp.asarray(p)[None], 4).tokens[0]
        np.testing.assert_array_equal(want, done[uid].tokens, err_msg=f"uid={uid}")
    _assert_drained_clean(eng)


# -- invariants through preemption / rollback / abort -------------------------


@pytest.mark.parametrize("family", list(FAMILIES), ids=list(FAMILIES))
@pytest.mark.parametrize("kind", ["swap", "recompute"])
def test_prefix_cache_through_preemption(family, kind):
    """Preempting a warm (cache-hit) request and resuming it preserves
    stream parity: swap keeps its shared-block references device-side
    (only private blocks travel to host), recompute re-admits through the
    cache — possibly forking deeper than the first admission did."""
    shared = _prompt(8, seed=11)
    p1 = np.concatenate([shared, _prompt(3, seed=12)])
    p2 = np.concatenate([shared, _prompt(3, seed=13)])
    eng, ref = _engine(family, prefix_cache=True,
                       preemption=PreemptionConfig(mode=kind))
    done = {}
    for o in eng.run([Request(uid=1, prompt=p1, max_new=6)]).values():
        done[o.uid] = o
    eng.submit(Request(uid=2, prompt=p2, max_new=8))
    e, early = _step_until(eng, 2, ReqState.RUNNING, min_outputs=2)
    assert e.cached_rows == 8  # admission forked on the 2 shared blocks
    kept_before = eng.pool.blocks_in_use
    eng._preempt(e, kind)
    if kind == "swap":
        assert e.state is ReqState.PREEMPTED_SWAPPED
        if eng.pool.has_paged:
            # shared blocks stayed on device, pinned by the kept references
            assert len(e.swap.kept) >= 1
            for _, b in e.swap.kept:
                assert eng.pool.allocator.refcount(b) >= 1
    else:
        assert e.state is ReqState.PREEMPTED_RECOMPUTE
    done.update({o.uid: o for o in early if o.finished})
    done.update(eng.run())
    for uid, p, n in [(1, p1, 6), (2, p2, 8)]:
        want = ref.generate(jnp.asarray(p)[None], n).tokens[0]
        np.testing.assert_array_equal(want, done[uid].tokens, err_msg=f"uid={uid}")
    assert eng.pool.blocks_in_use <= kept_before  # nothing leaked by the cycle
    _assert_drained_clean(eng)


@pytest.mark.parametrize("family", ["dense", "hybrid"], ids=["dense", "hybrid"])
def test_speculative_rollback_never_touches_shared_blocks(family):
    """Speculative decode over warm requests: rejected-draft rollback
    un-scatters only private rows — the pool-level guard would raise if a
    shared/cached block were addressed — and streams stay parity-exact."""
    shared = _prompt(8, seed=21)
    p1 = np.concatenate([shared, _prompt(3, seed=22)])
    p2 = np.concatenate([shared, _prompt(3, seed=23)])
    eng, _ = _engine(family, prefix_cache=True, spec_k=2, draft_ratio=0.5)
    base, _ = _engine(family, prefix_cache=True, spec_k=0, draft_ratio=0.5)
    reqs = [Request(uid=1, prompt=p1, max_new=6), Request(uid=2, prompt=p2, max_new=6)]
    done = eng.run([Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new)
                    for r in reqs])
    want = base.run([Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new)
                     for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(want[r.uid].tokens, done[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
    assert eng.spec_ticks >= 1
    _assert_drained_clean(eng)


def test_mid_prefill_abort_holding_shared_blocks():
    """Aborting a warm request mid-prefill releases exactly the references
    it held: the cache chain survives (including entries the aborted
    request itself registered), and a follow-up request resumes from the
    deepened chain to a bit-correct stream."""
    shared = _prompt(8, seed=31)
    p2 = np.concatenate([shared, _prompt(12, seed=32)])
    eng, ref = _engine(family="dense", prefix_cache=True, max_len=48)
    eng.run([Request(uid=1, prompt=shared, max_new=2)])
    eng.submit(Request(uid=2, prompt=p2, max_new=4))
    e, _ = _step_until(eng, 2, ReqState.PREFILLING)
    eng.step()  # push past the fork so it registers private blocks
    assert e.state is ReqState.PREFILLING
    assert 8 < e.prefill_pos < len(p2)
    entries_before = len(eng.pool.prefix_cache.entries)
    out = eng.abort(2)
    assert out is not None and out.finish_reason == "aborted"
    # the chain survived the abort — nothing was freed out from under it
    assert len(eng.pool.prefix_cache.entries) == entries_before
    # ... and it is still servable: deeper fork (the aborted request's own
    # registrations), same bits
    done = eng.run([Request(uid=3, prompt=p2, max_new=4)])
    want = ref.generate(jnp.asarray(p2)[None], 4).tokens[0]
    np.testing.assert_array_equal(want, done[3].tokens)
    pc = eng.pool.prefix_cache
    assert pc.hits >= 2
    assert pc.tokens_saved >= 8 + 12  # uid 3 forked past uid 2's fork point
    # NOTE: _assert_drained_clean evicts the whole index, so it must come last
    _assert_drained_clean(eng)


def test_abort_while_swapped_releases_shared_references():
    """A swapped-out warm request holds device-side references on its
    shared blocks; aborting it in that state must drop exactly those."""
    shared = _prompt(8, seed=41)
    p2 = np.concatenate([shared, _prompt(3, seed=42)])
    eng, _ = _engine(family="dense", prefix_cache=True,
                     preemption=PreemptionConfig(mode="swap"))
    eng.run([Request(uid=1, prompt=shared, max_new=2)])
    eng.submit(Request(uid=2, prompt=p2, max_new=6)
               )
    e, _ = _step_until(eng, 2, ReqState.RUNNING, min_outputs=1)
    eng._preempt(e, "swap")
    assert e.state is ReqState.PREEMPTED_SWAPPED and len(e.swap.kept) >= 1
    shared_ids = [b for _, b in e.swap.kept]
    for b in shared_ids:
        assert eng.pool.allocator.refcount(b) == 1  # pinned by the swap
    out = eng.abort(2)
    assert out is not None
    for b in shared_ids:
        assert eng.pool.allocator.refcount(b) == 0  # reference dropped
    _assert_drained_clean(eng)


# -- eviction under pressure --------------------------------------------------


def test_cache_eviction_under_block_pressure():
    """When the free stack runs dry, allocation reclaims refcount-0 cached
    blocks leaf-first (LRU) instead of failing — and a post-eviction
    lookup of the evicted prefix degrades to a (correct) shallower hit or
    miss, never to wrong KV."""
    eng, ref = _engine(family="dense", prefix_cache=True, max_slots=2,
                       num_blocks=9, max_len=24)
    # 16-token prompts: request 1 retains 4 cached blocks, leaving 4 free of
    # the 8 usable — request 2 needs 5, so the free stack alone can't serve it
    pa = _prompt(16, seed=51)
    pb = _prompt(16, seed=52)
    done = eng.run([Request(uid=1, prompt=pa, max_new=3)])
    cached0 = len(eng.pool.prefix_cache.entries)
    assert cached0 >= 2
    # an unrelated prompt needs more blocks than the free stack holds:
    # admission must evict cached blocks rather than stall
    done2 = eng.run([Request(uid=2, prompt=pb, max_new=3)])
    assert eng.pool.prefix_cache.evictions >= 1
    for uid, p, d in [(1, pa, done), (2, pb, done2)]:
        want = ref.generate(jnp.asarray(p)[None], 3).tokens[0]
        np.testing.assert_array_equal(want, d[uid].tokens)
    # whatever survives is still internally consistent
    _assert_drained_clean(eng)


def test_admit_prefix_degrades_cleanly_when_chain_is_the_only_slack():
    """``admit_prefix`` pins the hit chain before allocating the private
    remainder; when the chain was the pool's only reclaimable slack that
    allocation must fail all-or-nothing: None back, every refcount, the
    retained counter, and the free-slot stack exactly restored — and a
    cold admission of the first-chunk footprint must then succeed by
    evicting the unpinnable chain."""
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=3, max_len=32, block_size=4,
                     num_blocks=7, prefix_cache=True)
    prompt = _prompt(20, seed=81)
    s0 = pool.admit(16)
    pool.lengths[s0] = 16
    pool.register_prefix(s0, prompt, 16, resumable=True)
    s1 = pool.admit(8)  # blocker: consumes the remaining free blocks
    assert pool.n_free_blocks == 0
    pool.free(s0)  # chain retained at refcount 0: the only slack
    assert pool.n_reclaimable_blocks == 4
    fork, entries = pool.lookup_prefix(prompt, CT)
    assert fork == 16 and len(entries) == 4
    free_slots = list(pool._free_slots)
    assert pool.admit_prefix(20, entries) is None
    assert pool.n_reclaimable_blocks == 4  # references dropped back
    for e in entries:
        assert pool.allocator.refcount(e.block) == 0
    assert pool._free_slots == free_slots and pool.n_free_blocks == 0
    pool.cancel_prefix_hit(fork)
    pc = pool.prefix_cache
    assert pc.hits == 0 and pc.misses == 1 and pc.tokens_saved == 0
    s2 = pool.admit(4)  # cold path: eviction reclaims the chain
    assert s2 is not None and pc.evictions >= 1
    pool.free(s2)
    pool.free(s1)


def test_warm_admission_falls_back_cold_under_pin_pressure():
    """Engine regression: a cache hit whose chain is the pool's only
    reclaimable slack used to crash the admission tick (``admit_prefix``
    -> None -> assert).  The engine must instead degrade that admission
    to a cold prefill — evicting the unpinnable chain under its own
    allocation — with telemetry canceled back to a miss and streams still
    reference-identical."""
    eng, ref = _engine(family="dense", prefix_cache=True, max_slots=2,
                       num_blocks=8, max_len=32, decode_chunk=1)
    pa = _prompt(16, seed=91)
    pd = _prompt(8, seed=92)
    pw = np.concatenate([pa, _prompt(4, seed=93)])
    done = dict(eng.run([Request(uid=1, prompt=pa, max_new=1)]))
    assert eng.pool.n_reclaimable_blocks == 4  # uid 1's chain is retained
    # drive an unrelated request until it drains the free stack to zero
    # while the chain is the entire remaining (reclaimable) supply
    eng.submit(Request(uid=2, prompt=pd, max_new=6))
    for _ in range(100):
        eng.step()
        e2 = eng.lc.entries.get(2)
        if (e2 is not None and e2.state is ReqState.RUNNING
                and eng.pool.n_free_blocks == 0):
            break
    else:
        raise AssertionError("never reached the zero-free pressure window")
    eng.submit(Request(uid=3, prompt=pw, max_new=2))
    eng.step()  # admission tick: warm bind fails, cold fallback admits
    e3 = eng.lc.entries[3]
    assert e3.slot >= 0 and e3.cached_rows == 0  # admitted, cold
    pc = eng.pool.prefix_cache
    assert pc.hits == 0  # the unbindable hit was canceled back to a miss
    done.update(eng.run())
    for uid, p, n in [(1, pa, 1), (2, pd, 6), (3, pw, 2)]:
        want = ref.generate(jnp.asarray(p)[None], n).tokens[0]
        np.testing.assert_array_equal(want, done[uid].tokens, err_msg=f"uid={uid}")
    _assert_drained_clean(eng)


def test_blockless_cap_evicts_lru_leaves_first():
    """Unit: block-less chains (pure-state families) are capped by LRU
    leaf-first eviction at insert time — the oldest chain goes, the
    newest survives whole."""
    pc = PrefixCache(4, max_blockless=4)
    a, b, c = (np.arange(s, s + 8, dtype=np.int32) for s in (0, 8, 16))
    pc.insert_chain(a, 8, None, resumable=True)  # 2 entries
    pc.insert_chain(b, 8, None, resumable=True)  # 4 entries: at cap
    assert len(pc.entries) == 4
    pc.insert_chain(c, 8, None, resumable=True)  # 6 -> evict chain a
    assert len(pc.entries) == 4 and pc.evictions == 2
    ext = lambda p: np.concatenate([p, np.zeros(2, np.int32)])
    assert pc.lookup(ext(a), 4)[0] == 0  # evicted: clean miss
    assert pc.lookup(ext(b), 4)[0] == 8  # survivors intact
    assert pc.lookup(ext(c), 4)[0] == 8


def test_blockless_cache_is_bounded_for_pure_state_family():
    """rwkv6 regression: block-less entries carry full state-row resume
    snapshots and see no allocation pressure (no paged blocks), so
    without a cap a stream of distinct prompts would grow device memory
    without bound.  The cap holds, and post-eviction lookups still serve
    bit-identical streams."""
    eng, ref = _engine(family="rwkv6", prefix_cache=True)
    eng.pool.prefix_cache.max_blockless = 5
    prompts = [_prompt(12, seed=100 + i) for i in range(8)]
    for i, p in enumerate(prompts):
        eng.run([Request(uid=i, prompt=p, max_new=2)])
    pc = eng.pool.prefix_cache
    assert len(pc.entries) <= 5 and pc.evictions >= 1
    # an evicted-chain prompt degrades to a shallower hit or miss, never
    # to wrong state
    done = eng.run([Request(uid=99, prompt=prompts[0], max_new=2)])
    want = ref.generate(jnp.asarray(prompts[0])[None], 2).tokens[0]
    np.testing.assert_array_equal(want, done[99].tokens)
    _assert_drained_clean(eng)


def test_swap_out_all_shared_reports_zero_paged_bytes():
    """Telemetry regression: a request whose every block is
    cache-registered swaps out zero private blocks, and the padded
    trash-block gather must not be booked as live bytes moved."""
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=2, max_len=32, block_size=4,
                     num_blocks=7, prefix_cache=True)
    prompt = _prompt(8, seed=71)
    slot = pool.admit(8)
    pool.lengths[slot] = 8
    pool.register_prefix(slot, prompt, 8, resumable=True)
    sw = pool.swap_out(slot)
    assert sw.n_blocks == 0 and len(sw.kept) == 2
    assert sw.nbytes == 0  # dense family: every cache leaf is paged
    s2 = pool.swap_in(sw)
    assert s2 is not None and pool.held_blocks(s2) == 2
    assert int(pool.lengths[s2]) == 8
    pool.free(s2)


# -- pool-leak regression over randomized shared-prefix workloads -------------


@settings(max_examples=6, deadline=None)
@given(
    st.integers(min_value=0, max_value=2 ** 31 - 1),
    st.lists(st.tuples(st.integers(min_value=0, max_value=2),  # prefix family
                       st.integers(min_value=0, max_value=9),  # tail length
                       st.integers(min_value=1, max_value=5),  # max_new
                       st.integers(min_value=0, max_value=6)),  # arrival
             min_size=1, max_size=6),
)
def test_pool_leak_regression_randomized_shared_prefix(seed, spec):
    """Drain a randomized shared-prefix workload on a tight pool (eviction
    + preemption in play): streams match single-request serving, and the
    drained pool holds ONLY refcount-0 cache-indexed blocks — evicting the
    index restores the initial free stack exactly."""
    rng = np.random.RandomState(seed % (2 ** 31))
    prefixes = [rng.randint(3, 101, size=8).astype(np.int32) for _ in range(3)]
    eng, ref = _engine(family="dense", prefix_cache=True, max_slots=2,
                       num_blocks=13, max_len=32,
                       preemption=PreemptionConfig(mode="recompute"))
    reqs = []
    for i, (fam, tl, mn, arr) in enumerate(spec):
        tail = rng.randint(3, 101, size=tl).astype(np.int32)
        p = np.concatenate([prefixes[fam], tail])
        reqs.append(Request(uid=i, prompt=p, max_new=mn, arrival=arr))
    done = eng.run(reqs)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
    _assert_drained_clean(eng)


# -- telemetry ----------------------------------------------------------------


def test_hit_rate_and_tokens_saved_telemetry():
    """N requests over one shared prefix: first misses, the rest hit —
    hit rate (N-1)/N and tokens_saved = (N-1) * fork."""
    shared = _prompt(12, seed=61)
    eng, _ = _engine(family="dense", prefix_cache=True)
    N = 5
    for i in range(N):
        tail = _prompt(2, seed=70 + i, lo=4)
        eng.run([Request(uid=i, prompt=np.concatenate([shared, tail]), max_new=2)])
    pc = eng.pool.prefix_cache
    assert pc.misses == 1 and pc.hits == N - 1
    assert pc.hit_rate == pytest.approx((N - 1) / N)
    assert pc.tokens_saved == (N - 1) * 12
