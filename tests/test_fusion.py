"""Rank-aggregation properties (paper Sec. 3.4 / App. A)."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.core.fusion import (
    GlassConfig,
    block_aggregate,
    glass_scores,
    jaccard,
    ranks_ascending,
    select,
    select_blocks,
    select_shard_balanced,
    select_topk,
)

floats = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32)


@given(st.lists(floats, min_size=2, max_size=64))
@settings(max_examples=50, deadline=None)
def test_ranks_are_permutation(vals):
    r = np.asarray(ranks_ascending(jnp.asarray(vals, jnp.float32)))
    assert sorted(r.tolist()) == list(range(1, len(vals) + 1))


well_scaled = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32).filter(
    lambda v: v == 0.0 or abs(v) > 1e-2  # keep the f32 affine transform strictly monotone
)


@given(st.lists(well_scaled, min_size=3, max_size=32, unique=True), st.floats(0.5, 5.0))
@settings(max_examples=40, deadline=None)
def test_rank_monotone_invariance(vals, scale):
    """Fusion is invariant to monotone transforms of either signal."""
    x = jnp.asarray(vals, jnp.float32)
    r1 = np.asarray(ranks_ascending(x))
    r2 = np.asarray(ranks_ascending(x * scale + 7.0))
    np.testing.assert_array_equal(r1, r2)


def test_tie_break_by_index():
    x = jnp.asarray([1.0, 2.0, 2.0, 0.5])
    r = np.asarray(ranks_ascending(x))
    # ties (2.0, 2.0): lower index gets the lower rank
    assert r[1] < r[2]
    idx, _ = select_topk(x, 2)
    assert idx.tolist() == [1, 2]


def test_map_consensus_equals_borda_bruteforce():
    """App. A: argmin over permutations of the Mallows objective == sorting
    by the weighted rank sum (checked exhaustively for m = 5)."""
    rng = np.random.default_rng(0)
    m = 5
    for _ in range(5):
        local = rng.normal(size=m)
        glob = rng.normal(size=m)
        bl, bg = 0.3, 0.7
        rl = np.asarray(ranks_ascending(jnp.asarray(local, jnp.float32)))
        rg = np.asarray(ranks_ascending(jnp.asarray(glob, jnp.float32)))
        best, best_val = None, np.inf
        for perm in itertools.permutations(range(m)):
            r = np.empty(m)
            for rank_pos, j in enumerate(perm):
                r[j] = rank_pos + 1
            val = bl * np.sum((rl - r) ** 2) + bg * np.sum((rg - r) ** 2)
            if val < best_val - 1e-12:
                best_val, best = val, r
        s = bl * rl + bg * rg
        # MAP rank order == descending fused-score order
        order_map = np.argsort(-best)
        order_borda = np.argsort(-s, kind="stable")
        np.testing.assert_array_equal(order_map, order_borda)


def test_lambda_endpoints():
    rng = np.random.default_rng(1)
    local = jnp.asarray(rng.normal(size=16), jnp.float32)
    glob = jnp.asarray(rng.normal(size=16), jnp.float32)
    s0 = glass_scores(local, glob, lam=0.0)
    s1 = glass_scores(local, glob, lam=1.0)
    np.testing.assert_array_equal(np.argsort(-s0), np.argsort(-np.asarray(ranks_ascending(local))))
    np.testing.assert_array_equal(np.argsort(-s1), np.argsort(-np.asarray(ranks_ascending(glob))))


@given(st.integers(1, 7), st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_block_selection_density(nb_keep, bs):
    m = 8 * bs
    scores = jnp.asarray(np.random.default_rng(0).normal(size=m), jnp.float32)
    k = nb_keep * bs
    bidx, mask = select_blocks(scores, k, bs)
    assert float(mask.sum()) == k
    # mask is block-structured
    mm = np.asarray(mask).reshape(8, bs)
    assert set(np.unique(mm.sum(1))) <= {0.0, float(bs)}


def test_shard_balanced_counts():
    rng = np.random.default_rng(2)
    scores = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    idx, mask = select_shard_balanced(scores, 32, 4)
    per_shard = np.asarray(mask).reshape(3, 4, 16).sum(-1)
    assert (per_shard == 8).all()
    assert idx.shape == (3, 32)


def test_jaccard():
    a = jnp.asarray([1, 1, 0, 0], jnp.float32)
    b = jnp.asarray([1, 0, 1, 0], jnp.float32)
    assert float(jaccard(a, b)) == pytest.approx(1 / 3)
