"""Replica-sharded serving: the cluster invariant suite.

A :class:`~repro.serve.cluster.ClusterEngine` is a scheduling construct,
never a numerics one — so the suite's spine is bit-identity: a request
served by an N-replica cluster, *including one migrated between replicas
mid-flight*, must produce the token stream an undisturbed single engine
produces, np-equal, across all four model families and both greedy and
seeded-sampled decoding.  Around that:

  * migration legs: mid-decode (GLASS slot rows ride the ticket),
    mid-speculation (rollback first — the only legal SPECULATING exit),
    mid-prefill (chunk-aligned handoff, partial stat left-fold resumes
    at the destination over the same chunk boundaries);
  * abort while MIGRATING releases both pools completely (a full-swap
    ticket pins nothing on either side);
  * a hypothesis property: any drained cluster returns every replica's
    pool to its initial all-free state (slots, blocks, lengths);
  * global-queue policy parity: an N=1 cluster admits in exactly the
    single-engine order (the dispatcher adds routing, not reordering);
  * ``BlockPool.peek_prefix`` is a pure probe (the dispatcher calls it
    against every replica per admission: no LRU bump, no hit/miss skew);
  * the swap-store byte cap degrades the OLDEST swapped request to
    recompute, with telemetry and unchanged streams;
  * with a real ``data``-axis mesh, replica KV arenas commit to distinct
    devices (subprocess test: 8 forced host devices).

CI runs this module as its own lane (``-m cluster``) with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; everything but
the placement test also passes on one device (replicas then share it —
correct, just serialized).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.helpers import run_with_devices
from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.cluster import ClusterEngine, MigrationConfig
from repro.serve.engine import PagedEngine
from repro.serve.lifecycle import PreemptionConfig, ReqState
from repro.serve.sampling import SamplingParams

pytestmark = pytest.mark.cluster

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="cl-dense", family="dense", **BASE)
MOE = ModelConfig(name="cl-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="cl-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="cl-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})

FAMILIES = {
    "dense": (DENSE, "compact"),
    "moe": (MOE, "masked"),
    "rwkv6": (SSM, "masked"),
    "hybrid": (HYBRID, "compact"),
}

BS = 4  # block_size == chunk_tokens: every block boundary chunk-aligned
CT = 4

_BUILT = {}


def _model(cfg):
    if cfg.name not in _BUILT:
        model = build_model(cfg)
        _BUILT[cfg.name] = (model, model.init(jax.random.key(0)))
    return _BUILT[cfg.name]


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _engine_kw(family, **over):
    cfg, mode = FAMILIES[family]
    model, params = _model(cfg)
    glass = GlassConfig(density=0.5, selection="neuron", block_size=128,
                        draft_ratio=over.pop("draft_ratio", None))
    kw = dict(max_slots=2, max_len=32, block_size=BS, chunk_tokens=CT,
              glass=glass, global_prior=_prior_for(cfg), glass_mode=mode)
    kw.update(over)
    return model, params, kw


def _cluster(family, n_replicas=2, migration=None, **over):
    model, params, kw = _engine_kw(family, **over)
    return ClusterEngine(
        model, params, n_replicas=n_replicas,
        migration=migration or MigrationConfig(enabled=False), **kw,
    )


def _single(family, **over):
    model, params, kw = _engine_kw(family, **over)
    return PagedEngine(model, params, **kw)


def _prompt(n, seed=0):
    return np.random.RandomState(seed).randint(3, 101, size=n).astype(np.int32)


def _step_until(cl, uid, state, min_outputs=0, limit=300):
    """Step the CLUSTER until ``uid``'s entry (on its owner) hits
    ``state`` with at least ``min_outputs`` tokens; returns (entry, owner)."""
    for _ in range(limit):
        cl.step()
        owner = cl._owner.get(uid)
        if owner is None:
            continue
        e = cl.replicas[owner].lc.entries.get(uid)
        if e is not None and e.state is state and len(e.outputs) >= min_outputs:
            return e, owner
    raise AssertionError(f"uid {uid} never reached {state} on any replica")


def _assert_pool_pristine(eng):
    """The replica pool is back to its initial all-free state (no prefix
    cache in these engines: nothing may be retained)."""
    pool = eng.pool
    assert not pool.active.any()
    assert (pool.lengths == 0).all()
    assert pool.n_free_slots == pool.max_slots
    if pool.allocator is not None:
        assert pool.n_free_blocks == pool.num_blocks - 1
        assert pool.allocator.n_live == 0


# -- migration bit-identity across families and sampling policies -------------


@pytest.mark.parametrize("family", list(FAMILIES), ids=list(FAMILIES))
@pytest.mark.parametrize("sampled", [False, True], ids=["greedy", "sampled"])
def test_migration_bit_identity(family, sampled):
    """A request migrated between replicas mid-decode streams the exact
    tokens an undisturbed single engine streams — greedy and seeded-
    sampled (counter-based PRNG: position-keyed draws survive the move)."""
    sp = SamplingParams(temperature=0.8, top_k=20, seed=42) if sampled else None
    prompts = [_prompt(6, seed=1), _prompt(7, seed=2)]

    ref = _single(family)
    for i, p in enumerate(prompts):
        ref.add_request(p, 8, uid=i, sampling=sp)
    want = {u: np.asarray(f.tokens) for u, f in ref.run().items()}

    cl = _cluster(family)
    for i, p in enumerate(prompts):
        cl.add_request(p, 8, uid=i, sampling=sp)
    e, owner = _step_until(cl, 0, ReqState.RUNNING, min_outputs=2)
    moved_at = len(e.outputs)
    cl.migrate(0, 1 - owner)
    assert cl._owner[0] == 1 - owner
    assert cl.replicas[owner].migrations_out == 1
    assert cl.replicas[1 - owner].migrations_in == 1
    assert cl.migrations == 1 and cl.migration_bytes > 0
    done = cl.run()
    for u in want:
        np.testing.assert_array_equal(want[u], done[u].tokens, err_msg=f"uid={u}")
    assert moved_at < len(want[0])  # the move really happened mid-stream


def test_mid_speculation_migration():
    """A SPECULATING victim rolls back to its last accepted token before
    leaving (provisional draft tokens never cross engines), and the
    migrated stream still equals the undisturbed speculative run."""
    kw = dict(draft_ratio=0.5, spec_k=2)
    ref = _single("dense", **kw)
    ref.add_request(_prompt(6, seed=1), 8, uid=0)
    want = np.asarray(ref.run()[0].tokens)

    cl = _cluster("dense", **kw)
    cl.add_request(_prompt(6, seed=1), 8, uid=0)
    e, owner = _step_until(cl, 0, ReqState.RUNNING, min_outputs=1)
    src = cl.replicas[owner]
    src._spec_draft([e], 2)  # force mid-speculation: provisional drafts out
    assert e.state is ReqState.SPECULATING and e.spec_len == 2
    n_before = len(e.outputs) - e.spec_len
    cl.migrate(0, 1 - owner)
    dst_e = cl.replicas[1 - owner].lc.entries[0]
    assert len(dst_e.outputs) == n_before  # drafts rolled back, not shipped
    done = cl.run()
    np.testing.assert_array_equal(want, done[0].tokens)


@pytest.mark.parametrize("family", ["dense", "rwkv6"], ids=["dense", "rwkv6"])
def test_mid_prefill_migration(family):
    """A PREFILLING request hands off at its current chunk boundary: the
    partial GLASS stat left-fold travels with the ticket and keeps
    accumulating at the destination over the SAME chunk boundaries, so
    the stream is bit-identical to an unmigrated prefill."""
    prompt = _prompt(16, seed=5)  # 4 chunks of CT=4
    ref = _single(family)
    ref.add_request(prompt, 6, uid=0)
    want = np.asarray(ref.run()[0].tokens)

    cl = _cluster(family)
    cl.add_request(prompt, 6, uid=0)
    for _ in range(300):
        cl.step()
        owner = cl._owner.get(0)
        e = cl.replicas[owner].lc.entries.get(0) if owner is not None else None
        if (e is not None and e.state is ReqState.PREFILLING
                and 0 < e.prefill_pos < len(prompt)):
            break
    else:
        raise AssertionError("never caught the request mid-prefill")
    pos = e.prefill_pos
    assert pos % CT == 0  # migration runs between ticks: chunk-aligned
    cl.migrate(0, 1 - owner)
    dst = cl.replicas[1 - owner]
    assert dst.lc.entries[0].prefill_pos == pos
    done = cl.run()
    assert dst.lc.entries.get(0) is None  # finished (pruned) on the dest
    np.testing.assert_array_equal(want, done[0].tokens)


def test_abort_while_migrating_releases_both_sides():
    """Aborting a request that sits in MIGRATING on the destination (its
    ticket adopted, its splice not yet run) leaves BOTH pools pristine:
    the source released everything at migrate_out, and the destination's
    store pins nothing until swap-in."""
    cl = _cluster("dense")
    cl.add_request(_prompt(6, seed=1), 8, uid=0)
    e, owner = _step_until(cl, 0, ReqState.RUNNING, min_outputs=1)
    src, dst = cl.replicas[owner], cl.replicas[1 - owner]
    ticket = src.migrate_out(0)
    dst.migrate_in(ticket)
    cl._owner[0] = 1 - owner
    assert dst.lc.entries[0].state is ReqState.MIGRATING
    out = cl.abort(0)
    assert out is not None and out.finish_reason == "aborted"
    assert dst.lc.entries.get(0) is None
    _assert_pool_pristine(src)
    _assert_pool_pristine(dst)
    assert not src._work_remaining() and not dst._work_remaining()


# -- drained cluster restores every pool (property) ---------------------------


@settings(max_examples=15, deadline=None)
@given(
    spec=st.lists(
        st.tuples(
            st.integers(min_value=1, max_value=10),  # prompt length
            st.integers(min_value=1, max_value=6),  # max_new
            st.integers(min_value=0, max_value=4),  # arrival (cluster ticks)
        ),
        min_size=1, max_size=5,
    ),
    admission=st.sampled_from(["balanced", "round_robin"]),
)
def test_drained_cluster_restores_pools(spec, admission):
    """Whatever the workload and routing, a drained cluster returns every
    replica's pool to its initial free state — with hot-spot migration
    enabled and an aggressive threshold so moves actually happen."""
    cl = _cluster(
        "dense", admission=admission,
        migration=MigrationConfig(enabled=True, imbalance_tokens=8,
                                  min_remaining=2),
    )
    for i, (plen, new, arr) in enumerate(spec):
        cl.add_request(_prompt(plen, seed=i), new, uid=i, arrival=arr)
    done = cl.run()
    assert len(done) == len(spec)
    for i, (plen, new, arr) in enumerate(spec):
        assert done[i].tokens.shape[0] == new
    for eng in cl.replicas:
        _assert_pool_pristine(eng)
        assert not eng._work_remaining()
    assert cl._work_remaining() is False


# -- global-queue policy parity -----------------------------------------------


def test_n1_cluster_matches_single_engine_fifo():
    """An N=1 cluster is a pass-through: the global queue admits in
    exactly the single-engine FIFO order and every stream is identical —
    the dispatcher adds routing, never reordering."""
    spec = [(6, 5, 0), (4, 3, 0), (8, 4, 1), (5, 6, 3)]
    ref = _single("dense", max_slots=2)
    cl = _cluster("dense", n_replicas=1, max_slots=2)
    for i, (plen, new, arr) in enumerate(spec):
        p = _prompt(plen, seed=i)
        ref.add_request(p, new, uid=i, arrival=arr)
        cl.add_request(p, new, uid=i, arrival=arr)
    want = ref.run()
    done = cl.run()
    for i in range(len(spec)):
        np.testing.assert_array_equal(want[i].tokens, done[i].tokens)
    order = lambda outs: [u for u, _ in sorted(
        outs.items(), key=lambda kv: (kv[1].admitted_step, kv[0]))]
    assert order(want) == order(done)
    assert len(cl.admission_waits) == len(spec)


# -- peek_prefix is a pure probe ----------------------------------------------


def test_peek_prefix_probe_has_no_side_effects():
    """``BlockPool.peek_prefix`` returns what ``lookup`` would serve but
    mutates nothing: no hit/miss counts, no tokens-saved, no LRU bump —
    the dispatcher probes every replica per admission and N-1 of those
    probes route nowhere."""
    eng = _single("dense", prefix_cache=True, max_slots=2)
    shared = _prompt(12, seed=3)
    eng.add_request(shared, 4, uid=0)  # warm the chain
    eng.run()
    pool = eng.pool
    pc = pool.prefix_cache
    snap = (pc.hits, pc.misses, pc.tokens_saved, pc._tick, pc.inserts,
            pc.evictions, pc.retained)
    ticks = {k: e.tick for k, e in pc.entries.items()}

    probe = pool.peek_prefix(np.concatenate([shared, _prompt(3, seed=4)]), CT)
    assert probe == 12  # the full warmed chain is resumable
    assert pool.peek_prefix(_prompt(12, seed=9), CT) == 0  # miss probes too
    assert (pc.hits, pc.misses, pc.tokens_saved, pc._tick, pc.inserts,
            pc.evictions, pc.retained) == snap
    assert {k: e.tick for k, e in pc.entries.items()} == ticks

    # the probe PREDICTS the mutating lookup: same fork the admission gets
    fork, _ = pc.lookup(np.concatenate([shared, _prompt(3, seed=4)]), CT)
    assert fork == probe
    assert pc._tick > snap[3]  # and the real lookup does bump


# -- swap-store byte cap ------------------------------------------------------


def test_swap_store_cap_degrades_oldest():
    """Under a host swap-store byte cap, the OLDEST swapped request is
    degraded to recompute (releasing its store) instead of growing the
    store without bound — counted in telemetry, invisible in the streams."""
    model, params, kw = _engine_kw("dense")
    spec = [(8, 10, 0)] * 4  # 4 x (17 rows = 5 blocks) vs 6 usable blocks

    def serve(cap):
        eng = PagedEngine(
            model, params,
            preemption=PreemptionConfig(mode="swap", swap_store_cap_bytes=cap),
            **{**kw, "max_slots": 3, "num_blocks": 7},
        )
        for i, (plen, new, arr) in enumerate(spec):
            eng.add_request(_prompt(plen, seed=i), new, uid=i, arrival=arr)
        return eng, eng.run()

    free_eng, free_done = serve(None)  # uncapped: swaps accumulate freely
    assert free_eng.lc.preempted(kind="swap") >= 1, "workload must force swaps"
    assert free_eng.swap_cap_evictions == 0

    cap_eng, cap_done = serve(1)  # no store survives a 1-byte cap
    assert cap_eng.swap_cap_evictions >= 1
    assert cap_eng.lc.counts.get(
        ("preempted_swapped", "preempted_recompute"), 0) >= 1
    assert cap_eng.recompute_tokens > 0
    assert cap_eng.swap_store_bytes == 0  # nothing resident after the drain
    for i in range(len(spec)):  # degrade is a scheduling move, not a numerics one
        np.testing.assert_array_equal(free_done[i].tokens, cap_done[i].tokens)


# -- per-replica device placement (8 forced host devices) ---------------------


def test_replica_device_placement_and_streams():
    """With a data=2 mesh, the two replicas' KV arenas live on DISTINCT
    devices (dispatch-concurrent decode) and the streams still match a
    single engine bit-for-bit — placement is invisible in the tokens."""
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GlassConfig
        from repro.models import ModelConfig, build_model
        from repro.launch.mesh import make_host_mesh
        from repro.serve.cluster import ClusterEngine, MigrationConfig
        from repro.serve.engine import PagedEngine

        cfg = ModelConfig(name="cl-dev", family="dense", n_layers=2, d_model=48,
                          n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96,
                          vocab_size=101, dtype="float32", remat="none")
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        glass = GlassConfig(density=0.5, selection="neuron", block_size=128)
        prior = jnp.abs(jax.random.normal(jax.random.key(7),
                                          (cfg.n_layers, cfg.d_ff)))
        kw = dict(max_slots=2, max_len=32, block_size=4, chunk_tokens=4,
                  glass=glass, global_prior=prior)

        mesh = make_host_mesh(data=2, model=4)
        cl = ClusterEngine(model, params, n_replicas=2, mesh=mesh,
                           migration=MigrationConfig(enabled=False), **kw)
        devs = [
            {d for leaf in jax.tree.leaves(eng.pool.cache)
             for d in leaf.devices()}
            for eng in cl.replicas
        ]
        assert devs[0] and devs[1] and devs[0].isdisjoint(devs[1]), devs
        assert cl.replicas[0].programs.namespace == "replica0"
        assert all(
            name.startswith("replica1/")
            for name in cl.replicas[1].programs.sizes()
        )

        ref = PagedEngine(model, params, **kw)
        prompts = [np.random.RandomState(s).randint(3, 101, size=6).astype(np.int32)
                   for s in range(3)]
        for i, p in enumerate(prompts):
            ref.add_request(p, 6, uid=i)
            cl.add_request(p, 6, uid=i)
        want = ref.run()
        done = cl.run()
        for i in range(3):
            np.testing.assert_array_equal(want[i].tokens, done[i].tokens)
        # migration across DEVICES: host-roundtrip wire, still bit-exact
        cl2 = ClusterEngine(model, params, n_replicas=2, mesh=mesh,
                            migration=MigrationConfig(enabled=False), **kw)
        from repro.serve.lifecycle import ReqState
        cl2.add_request(prompts[0], 6, uid=0)
        for _ in range(200):
            cl2.step()
            owner = cl2._owner.get(0)
            e = cl2.replicas[owner].lc.entries.get(0) if owner is not None else None
            if e is not None and e.state is ReqState.RUNNING and len(e.outputs) >= 2:
                cl2.migrate(0, 1 - owner)
                break
        done2 = cl2.run()
        np.testing.assert_array_equal(want[0].tokens, done2[0].tokens)
        print("PLACEMENT-OK")
        """,
        n_devices=8,
    )
    assert "PLACEMENT-OK" in out
