"""Property-style invariants of ``build_masks`` across model families.

Checks the paper's selection contract (Sec. 3.4): exactly k unique sorted
units per layer at any density; lam=0 reduces to GRIFFIN (local-only
ranking, prior-independent); lam=1 reduces to the static global mask
(local-independent); and the slot-stacked batched path is exactly the
per-request path.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback in tests/hypothesis_compat.py.
"""
import jax
import jax.numpy as jnp
import numpy as np

from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig, build_masks
from repro.core.fusion import select_topk

L, M, E = 3, 64, 4

# family -> per-layer stat/prior shapes (hybrid collapses to one shared signal)
FAMILY_SHAPES = {
    "dense": (L, M),
    "moe": (L, E, M),
    "hybrid": (M,),
}


def _stats(shape, seed):
    key = jax.random.key(seed)
    sum_abs = jnp.abs(jax.random.normal(key, shape))
    count = jnp.asarray(7.0)
    return {"sum_abs": sum_abs, "count": count}


def _prior(shape, seed):
    return jnp.abs(jax.random.normal(jax.random.key(seed), shape))


@given(
    st.sampled_from(list(FAMILY_SHAPES)),
    st.floats(0.05, 0.95),
    st.floats(0.0, 1.0),
    st.integers(0, 5),
)
@settings(max_examples=30, deadline=None)
def test_selects_exactly_k_unique_sorted(family, density, lam, seed):
    shape = FAMILY_SHAPES[family]
    ms = build_masks(_stats(shape, seed), _prior(shape, seed + 100),
                     GlassConfig(density=density, lam=lam))
    k = max(1, int(round(density * M)))
    idx = np.asarray(ms.idx).reshape(-1, ms.idx.shape[-1])
    mask = np.asarray(ms.mask).reshape(-1, M)
    assert idx.shape[-1] == k
    for row, mrow in zip(idx, mask):
        assert len(set(row.tolist())) == k  # unique
        assert (np.diff(row) > 0).all()  # strictly sorted ascending
        assert mrow.sum() == k and set(np.nonzero(mrow)[0]) == set(row.tolist())


@given(st.sampled_from(list(FAMILY_SHAPES)), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_lam0_is_local_only_griffin(family, seed):
    """lam=0: selection == top-k of the local signal, for ANY prior."""
    shape = FAMILY_SHAPES[family]
    stats = _stats(shape, seed)
    g = GlassConfig(density=0.5, lam=0.0)
    ms1 = build_masks(stats, _prior(shape, seed + 1), g)
    ms2 = build_masks(stats, _prior(shape, seed + 2), g)
    np.testing.assert_array_equal(np.asarray(ms1.idx), np.asarray(ms2.idx))
    local = stats["sum_abs"] / 7.0
    want, _ = select_topk(local.reshape(-1, M), g.k_of(M))
    np.testing.assert_array_equal(
        np.asarray(ms1.idx).reshape(-1, g.k_of(M)), np.asarray(want)
    )


@given(st.sampled_from(list(FAMILY_SHAPES)), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_lam1_is_static_global(family, seed):
    """lam=1: selection == top-k of the prior, for ANY local stats."""
    shape = FAMILY_SHAPES[family]
    prior = _prior(shape, seed)
    g = GlassConfig(density=0.5, lam=1.0)
    ms1 = build_masks(_stats(shape, seed + 1), prior, g)
    ms2 = build_masks(_stats(shape, seed + 2), prior, g)
    np.testing.assert_array_equal(np.asarray(ms1.idx), np.asarray(ms2.idx))
    want, _ = select_topk(prior.reshape(-1, M), g.k_of(M))
    np.testing.assert_array_equal(
        np.asarray(ms1.idx).reshape(-1, g.k_of(M)), np.asarray(want)
    )


@given(st.sampled_from(list(FAMILY_SHAPES)), st.floats(0.0, 1.0), st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_slot_axis_matches_per_request(family, lam, seed):
    """The continuous-batching batched build == per-request builds."""
    shape = FAMILY_SHAPES[family]
    prior = _prior(shape, seed + 50)
    g = GlassConfig(density=0.5, lam=lam)
    stats = [_stats(shape, seed + i) for i in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stats)
    ms = build_masks(stacked, prior, g, slot_axis=True)
    for j, st_j in enumerate(stats):
        ref = build_masks(st_j, prior, g)
        np.testing.assert_array_equal(np.asarray(ref.idx), np.asarray(ms.idx[:, j]))
        np.testing.assert_array_equal(np.asarray(ref.mask), np.asarray(ms.mask[:, j]))
