"""Optional-``hypothesis`` shim for the property-style tests.

When the real library is installed (see requirements-dev.txt) this module
re-exports it untouched, so CI runs the full randomized search.  On a clean
environment without ``hypothesis`` it falls back to a tiny deterministic
generator: each ``@given`` test runs a fixed number of seeded pseudo-random
examples.  That keeps ``pytest -q`` collecting (and meaningfully exercising)
every module with zero extra dependencies.

The fallback implements only the strategy surface used in this repo:
``floats`` (+ ``.filter``/``.map``), ``lists`` (min/max_size, unique),
``integers``, ``sampled_from``, ``booleans``, ``text``, ``just``, ``tuples``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random
    import string
    import zlib

    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 12

    class _Strategy:
        def __init__(self, gen):
            self._gen = gen

        def example(self, rnd: random.Random):
            return self._gen(rnd)

        def filter(self, pred):
            def gen(rnd):
                for _ in range(10_000):
                    v = self._gen(rnd)
                    if pred(v):
                        return v
                raise RuntimeError("fallback strategy filter never satisfied")

            return _Strategy(gen)

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._gen(rnd)))

    class _St:
        @staticmethod
        def floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=64):
            del allow_nan, width  # the fallback never generates NaN/inf

            def gen(rnd):
                return rnd.uniform(min_value, max_value)

            return _Strategy(gen)

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: rnd.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rnd: value)

        @staticmethod
        def text(alphabet=string.printable, max_size=32, min_size=0):
            alphabet = list(alphabet)

            def gen(rnd):
                n = rnd.randint(min_size, max_size)
                return "".join(alphabet[rnd.randrange(len(alphabet))] for _ in range(n))

            return _Strategy(gen)

        @staticmethod
        def lists(elements, min_size=0, max_size=16, unique=False):
            def gen(rnd):
                n = rnd.randint(min_size, max_size)
                if not unique:
                    return [elements.example(rnd) for _ in range(n)]
                out, seen = [], set()
                for _ in range(10_000):
                    if len(out) == n:
                        break
                    v = elements.example(rnd)
                    if v not in seen:
                        seen.add(v)
                        out.append(v)
                return out

            return _Strategy(gen)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rnd: tuple(s.example(rnd) for s in strategies))

    st = _St()

    def given(*gargs, **gkwargs):
        def deco(fn):
            def wrapper():
                # seed per test name: crc32 is stable across processes (unlike
                # hash(), which is salted), so failures reproduce exactly
                rnd = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(FALLBACK_EXAMPLES):
                    vals = [s.example(rnd) for s in gargs]
                    kw = {k: s.example(rnd) for k, s in gkwargs.items()}
                    fn(*vals, **kw)

            # NOT functools.wraps: pytest must see a zero-arg signature, or it
            # would try to resolve the strategy parameters as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(*args, **kwargs):  # noqa: ARG001 - accepted and ignored
        def deco(fn):
            return fn

        return deco
