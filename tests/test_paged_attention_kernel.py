"""Fused paged-attention kernel suite (``-m kernels`` CI lane).

Three layers of evidence, bottom-up:

  * KERNEL vs reference — the Pallas kernel against a dense gather +
    masked-softmax reference on hostile pool states: block tables with
    holes (padding entries at trash block 0), garbage in rows past the
    frontier, frontier-partial blocks, sliding windows, softcap, and
    T > 1 query chunks.  allclose, because the online softmax is a
    different summation order than the reference's dense softmax.
  * BIT-level contracts — the properties the serving engine builds on,
    asserted with ``==`` not allclose: a T-wide forward equals T
    sequential single-query calls (the parallel-verify contract), and
    output is invariant to the pow2 ``nb`` bucket the table is padded to
    (dead entries stream the trash block but mask to exact zeros).
  * MODEL/ENGINE level — decode_step under ``attn_mode="paged_pallas"``
    against the gather path for every attention family, verify_steps'
    one-forward parallel mode against the sequential scan (tokens AND
    cache bitwise), and the compiled-program churn invariant: replaying
    an identical engine workload must not add a single jit variant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import paged_attention
from repro.models import ModelConfig, build_model

pytestmark = pytest.mark.kernels

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="pk-dense", family="dense", **BASE)
MOE = ModelConfig(name="pk-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
HYBRID = ModelConfig(name="pk-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})

GLOBAL = 2**30


def _reference(q, cache_k, cache_v, block_table, cache_len, window,
               softcap=None, scale=None):
    """Dense gather + masked softmax — mirrors the gather path of
    attention_decode_paged, shapes (B,T,K,G,hd) against (N,bs,K,hd)."""
    B, T, K, G, hd = q.shape
    nb = block_table.shape[1]
    bs = cache_k.shape[1]
    scale = scale if scale is not None else hd**-0.5
    kg = np.asarray(cache_k)[np.asarray(block_table)].reshape(B, nb * bs, K, hd)
    vg = np.asarray(cache_v)[np.asarray(block_table)].reshape(B, nb * bs, K, hd)
    qpos = np.asarray(cache_len)[:, None] + np.arange(T)[None]  # (B, T)
    kpos = np.arange(nb * bs)
    mask = (qpos[:, :, None] >= kpos) & ((qpos[:, :, None] - kpos) < window)
    s = np.einsum("btkgd,bnkd->btkgn", np.asarray(q, np.float32),
                  kg.astype(np.float32)) * scale
    if softcap is not None:
        s = softcap * np.tanh(s / softcap)
    s = np.where(mask[:, :, None, None, :], s, -np.inf)
    s = s - s.max(-1, keepdims=True)
    p = np.exp(s)
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("btkgn,bnkd->btkgd", p, vg.astype(np.float32))


def _pool(rng, num_blocks=9, bs=8, K=2, hd=12):
    cache_k = rng.randn(num_blocks, bs, K, hd).astype(np.float32)
    cache_v = rng.randn(num_blocks, bs, K, hd).astype(np.float32)
    return jnp.asarray(cache_k), jnp.asarray(cache_v)


@pytest.mark.parametrize("window,softcap", [(GLOBAL, None), (6, None),
                                            (GLOBAL, 30.0), (3, 12.0)])
def test_kernel_matches_reference(window, softcap):
    """Holes, trash rows, and frontier-partial blocks: the pool carries
    garbage everywhere the table/frontier says is dead, and the kernel
    must reproduce the reference that never reads those rows."""
    rng = np.random.RandomState(0)
    B, T, K, G, hd, bs = 2, 3, 2, 2, 12, 8
    cache_k, cache_v = _pool(rng, bs=bs, K=K, hd=hd)
    # slot 0: 7 live rows in block 1 (partial frontier block), padding -> 0
    # slot 1: 13 live rows across blocks 3,4 (block 4 partial), hole at [1]=0
    btab = jnp.asarray([[1, 0, 0, 0], [3, 4, 0, 0]], jnp.int32)
    clen = jnp.asarray([4, 10], jnp.int32)  # + T new rows scattered below
    q = jnp.asarray(rng.randn(B, T, K, G, hd), jnp.float32)
    newk = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    newv = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    pos = clen[:, None] + jnp.arange(T)[None]
    pages = jnp.take_along_axis(btab, pos // bs, axis=1)
    cache_k = cache_k.at[pages, pos % bs].set(newk)
    cache_v = cache_v.at[pages, pos % bs].set(newv)
    out = paged_attention(q, cache_k, cache_v, btab, clen,
                          jnp.int32(window), softcap=softcap)
    ref = _reference(q, cache_k, cache_v, btab, clen, window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5, rtol=1e-5)


def test_kernel_ignores_trash_and_dead_rows():
    """Poisoning the trash block, the rows past each frontier, and every
    unreferenced block must not move a single output bit."""
    rng = np.random.RandomState(1)
    B, T, K, G, hd, bs = 2, 1, 2, 2, 12, 8
    cache_k, cache_v = _pool(rng, bs=bs, K=K, hd=hd)
    btab = jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32)
    clen = jnp.asarray([11, 5], jnp.int32)
    q = jnp.asarray(rng.randn(B, T, K, G, hd), jnp.float32)
    pos = clen[:, None]
    pages = jnp.take_along_axis(btab, pos // bs, axis=1)
    newk = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    cache_k = cache_k.at[pages, pos % bs].set(newk)
    cache_v = cache_v.at[pages, pos % bs].set(newk)
    out = paged_attention(q, cache_k, cache_v, btab, clen, jnp.int32(GLOBAL))
    poison = 1e6
    pk, pv = np.asarray(cache_k).copy(), np.asarray(cache_v).copy()
    pk[0] = poison; pv[0] = poison            # trash block
    pk[5:] = poison; pv[5:] = poison          # unreferenced blocks
    pk[2, 5:] = poison; pv[2, 5:] = poison    # rows past slot 0's frontier
    pk[3, 6:] = poison; pv[3, 6:] = poison    # rows past slot 1's frontier
    out_p = paged_attention(q, jnp.asarray(pk), jnp.asarray(pv), btab, clen,
                            jnp.int32(GLOBAL))
    assert bool((out == out_p).all())


def test_kernel_pow2_bucket_invariance():
    """The same logical state padded to wider nb buckets (extra entries at
    trash block 0) must produce bitwise identical output — the engine's
    pow2 table bucketing rides on this."""
    rng = np.random.RandomState(2)
    B, T, K, G, hd, bs = 2, 2, 2, 2, 12, 8
    cache_k, cache_v = _pool(rng, num_blocks=9, bs=bs, K=K, hd=hd)
    clen = jnp.asarray([6, 3], jnp.int32)
    q = jnp.asarray(rng.randn(B, T, K, G, hd), jnp.float32)
    tabs = {
        2: jnp.asarray([[1, 2], [3, 0]], jnp.int32),
        4: jnp.asarray([[1, 2, 0, 0], [3, 0, 0, 0]], jnp.int32),
        8: jnp.asarray([[1, 2, 0, 0, 0, 0, 0, 0],
                        [3, 0, 0, 0, 0, 0, 0, 0]], jnp.int32),
    }
    pos = clen[:, None] + jnp.arange(T)[None]
    pages = jnp.take_along_axis(tabs[4], pos // bs, axis=1)
    newk = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    cache_k = cache_k.at[pages, pos % bs].set(newk)
    cache_v = cache_v.at[pages, pos % bs].set(newk)
    outs = [
        np.asarray(paged_attention(q, cache_k, cache_v, tab, clen,
                                   jnp.int32(GLOBAL)))
        for tab in tabs.values()
    ]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


def test_kernel_parallel_queries_bitwise_equal_sequential():
    """The parallel-verify contract at kernel level: a T-wide call answers
    each query with exactly the bits of a T=1 call at that position (the
    query axis lives on the grid, so the traced op graph per (slot, query,
    head) program is identical whatever T is)."""
    rng = np.random.RandomState(3)
    B, T, K, G, hd, bs = 2, 4, 2, 2, 12, 8
    cache_k, cache_v = _pool(rng, num_blocks=9, bs=bs, K=K, hd=hd)
    btab = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    clen = jnp.asarray([7, 5], jnp.int32)
    q = jnp.asarray(rng.randn(B, T, K, G, hd), jnp.float32)
    newk = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    newv = jnp.asarray(rng.randn(B, T, K, hd), jnp.float32)
    pos = clen[:, None] + jnp.arange(T)[None]
    pages = jnp.take_along_axis(btab, pos // bs, axis=1)
    cache_k = cache_k.at[pages, pos % bs].set(newk)
    cache_v = cache_v.at[pages, pos % bs].set(newv)
    wide = np.asarray(paged_attention(q, cache_k, cache_v, btab, clen,
                                      jnp.int32(GLOBAL)))
    for t in range(T):
        one = np.asarray(paged_attention(q[:, t:t + 1], cache_k, cache_v,
                                         btab, clen + t, jnp.int32(GLOBAL)))
        assert np.array_equal(wide[:, t:t + 1], one), f"query {t} diverged"


@pytest.mark.parametrize("cfg", [DENSE, MOE, HYBRID],
                         ids=["dense", "moe", "hybrid"])
def test_decode_step_gather_vs_pallas(cfg):
    """Model level: attn_mode='paged_pallas' agrees with the gather path
    (allclose — the online softmax is a different summation order) and
    picks the same argmax tokens, for every attention-carrying family."""
    from repro.serve.kv_pool import BlockPool

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    pool = BlockPool(model, max_slots=2, max_len=32, block_size=8)
    rng = np.random.RandomState(4)
    cache = jax.tree.map(
        lambda a, pg: jnp.asarray(rng.randn(*a.shape) * 0.3, a.dtype)
        if pg else a,
        pool.cache, pool.paged,
    )
    btab = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    clen = jnp.asarray([7, 5], jnp.int32)
    toks = jnp.asarray(rng.randint(3, 101, size=(2, 1)), jnp.int32)
    lg_g, _ = model.decode_step(params, toks, cache, clen,
                                block_table=btab, attn_mode="gather")
    lg_p, _ = model.decode_step(params, toks, cache, clen,
                                block_table=btab, attn_mode="paged_pallas")
    np.testing.assert_allclose(np.asarray(lg_g), np.asarray(lg_p),
                               atol=2e-5, rtol=2e-5)
    assert np.array_equal(np.asarray(jnp.argmax(lg_g, -1)),
                          np.asarray(jnp.argmax(lg_p, -1)))


def test_decode_step_parallel_bitwise_vs_sequential():
    """The full-step parallel-verify contract: one T-wide decode_step
    (pallas attention, block-sparse GLASS FFN) produces bitwise the
    logits and KV writes of T sequential single-token steps."""
    cfg = DENSE
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    L = cfg.n_layers
    rng = np.random.RandomState(5)
    NB, BS, K, HD = 9, 8, cfg.n_kv_heads, cfg.head_dim
    cache = {"k": jnp.asarray(rng.randn(L, NB, BS, K, HD), jnp.float32),
             "v": jnp.asarray(rng.randn(L, NB, BS, K, HD), jnp.float32)}
    btab = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    clen = jnp.asarray([7, 5], jnp.int32)
    feed = jnp.asarray(rng.randint(3, 101, size=(2, 4)), jnp.int32)
    bidx = jnp.asarray(rng.randint(0, 3, size=(L, 2, 2)), jnp.int32)
    scale = jnp.ones((L, 2, 2), jnp.float32)
    kw = dict(block_table=btab, attn_mode="paged_pallas",
              ffn_block_idx=bidx, ffn_block_size=32, ffn_block_scale=scale)

    @jax.jit
    def wide(pr, cache, feed, clen):
        return model.decode_step(pr, feed, cache, clen, **kw)

    @jax.jit
    def one(pr, cache, tok, clen):
        return model.decode_step(pr, tok[:, None], cache, clen, **kw)

    lw, cw = wide(params, cache, feed, clen)
    c, l = cache, clen
    for t in range(4):
        lg, c = one(params, c, feed[:, t], l)
        assert np.array_equal(np.asarray(lw[:, t]), np.asarray(lg[:, 0])), t
        l = l + 1
    for name in ("k", "v"):
        assert np.array_equal(np.asarray(cw[name]), np.asarray(c[name])), name


def test_verify_steps_parallel_matches_sequential():
    """API level: Model.verify_steps(parallel=True) returns the same
    verdicts and the same cache bits as the sequential scan."""
    cfg = DENSE
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    L = cfg.n_layers
    rng = np.random.RandomState(6)
    NB, BS, K, HD = 9, 8, cfg.n_kv_heads, cfg.head_dim
    cache = {"k": jnp.asarray(rng.randn(L, NB, BS, K, HD), jnp.float32),
             "v": jnp.asarray(rng.randn(L, NB, BS, K, HD), jnp.float32)}
    btab = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    clen = jnp.asarray([7, 5], jnp.int32)
    toks = jnp.asarray(rng.randint(3, 101, size=(2, 4)), jnp.int32)
    kw = dict(block_table=btab, attn_mode="paged_pallas")
    g_s, c_s = model.verify_steps(params, toks, cache, clen, **kw)
    g_p, c_p = model.verify_steps(params, toks, cache, clen, parallel=True,
                                  **kw)
    assert np.array_equal(np.asarray(g_s), np.asarray(g_p))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_verify_steps_parallel_rejects_recurrent():
    cfg = ModelConfig(name="pk-ssm", family="ssm", rwkv_headdim=12, **BASE)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(1, 16)
    toks = jnp.zeros((1, 3), jnp.int32)
    with pytest.raises(NotImplementedError):
        model.verify_steps(params, toks, cache, jnp.zeros((1,), jnp.int32),
                           parallel=True)


def test_engine_program_cache_no_churn_on_replay():
    """Satellite invariant: the centralized ProgramCache reports ZERO new
    compiled variants when an identical workload replays — the pow2
    bucketing of gather widths and scan horizons is doing its job."""
    from repro.core import GlassConfig
    from repro.serve.engine import PagedEngine

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    g = GlassConfig(density=0.5, selection="block", block_size=32,
                    draft_ratio=0.5)
    prior = jnp.abs(jax.random.normal(jax.random.key(7),
                                      (DENSE.n_layers, DENSE.d_ff)))
    eng = PagedEngine(model, params, max_slots=2, max_len=64, block_size=8,
                      chunk_tokens=4, glass=g, global_prior=prior,
                      glass_mode="block_sparse", spec_k=3,
                      attn_mode="paged_pallas")

    def drive(uid0):
        rng = np.random.RandomState(9)
        for i, (l, n) in enumerate([(7, 10), (5, 8)]):
            eng.add_request(rng.randint(3, 101, size=l).astype(np.int32), n,
                            uid=uid0 + i)
        for _ in range(64):
            eng.step()
            if not eng.lc.entries:
                break
        assert not eng.lc.entries

    drive(0)
    assert eng.programs.total() > 0
    snap = eng.programs.snapshot()
    drive(100)  # identical workload, same engine
    assert eng.programs.misses_since(snap) == {}
