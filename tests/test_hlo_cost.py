"""Trip-count-aware HLO analyzer vs hand-computed costs."""
from tests.helpers import run_with_devices


def test_scan_flops_and_collectives_scaled():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(4, 2)
W = jax.ShapeDtypeStruct((8, 256, 512), jnp.bfloat16)
x = jax.ShapeDtypeStruct((16, 256), jnp.bfloat16)
def f(ws, x):
    def body(c, w):
        y = jnp.tanh(c @ w @ w.T)
        return y, jnp.sum(y)
    out, s = jax.lax.scan(body, x, ws)
    return jnp.sum(out) + jnp.sum(s)
with mesh:
    comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                                    NamedSharding(mesh, P("data", None)))).lower(W, x).compile()
rep = analyze_hlo(comp.as_text())
exp = (2*4*256*256 + 2*4*256*256) * 8  # per-device, 8 scanned layers
assert abs(rep.dot_flops - exp) / exp < 0.05, (rep.dot_flops, exp)
ar = rep.collective_bytes.get("all-reduce", 0)
assert ar >= 8 * 4 * 256 * 4  # >= 8 layer ARs of f32(4,256)
print("OK", rep.dot_flops, ar)
""")
    assert "OK" in out
