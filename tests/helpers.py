"""Run a python snippet in a subprocess with N forced host devices."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Executes ``code`` with XLA_FLAGS set pre-import; returns stdout.

    Raises on nonzero exit (stderr included in the error)."""
    preamble = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", preamble + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
