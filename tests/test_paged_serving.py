"""Paged KV block-table serving: allocator safety, chunked-prefill parity,
bounded per-tick prefill work, block-sparse decode, and admission policies.

The load-bearing property mirrors PR 1's: for greedy decoding the
``PagedEngine`` (block table + chunked prefill) must be TOKEN-IDENTICAL to
the slot-arena ``ContinuousEngine`` and to single-request static serving —
regardless of chunk boundaries, block reuse, interleaved prefill/decode
ticks, or which other requests share the pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.engine import ContinuousEngine, Engine, PagedEngine
from repro.serve.kv_pool import BlockAllocator, BlockPool, paged_layout
from repro.serve.scheduler import AdmissionPolicy, Request, Scheduler

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="pg-dense", family="dense", **BASE)
MOE = ModelConfig(name="pg-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="pg-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="pg-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _requests(spec, seed=0):
    """spec: list of (prompt_len, max_new, arrival)."""
    rng = np.random.RandomState(seed)
    return [
        Request(uid=i, prompt=rng.randint(3, 101, size=l).astype(np.int32),
                max_new=n, arrival=a)
        for i, (l, n, a) in enumerate(spec)
    ]


def _assert_paged_parity(cfg, glass, mode, spec, *, chunk_tokens=3, max_slots=2,
                         block_size=8, num_blocks=None):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prior = _prior_for(cfg) if glass else None
    reqs = _requests(spec)
    eng = PagedEngine(model, params, max_slots=max_slots, max_len=32,
                      block_size=block_size, num_blocks=num_blocks,
                      chunk_tokens=chunk_tokens, glass=glass,
                      global_prior=prior, glass_mode=mode)
    done = eng.run(reqs)
    ref = Engine(model, params, glass=glass, global_prior=prior, glass_mode=mode)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")
    return eng


# -- block allocator ----------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=13),
    st.lists(st.tuples(st.booleans(), st.integers(min_value=0, max_value=5)),
             max_size=40),
)
def test_block_allocator_properties(nb, ops):
    """Random alloc/free interleavings: handed-out blocks stay disjoint,
    the trash block is never handed out, and accounting balances."""
    alloc = BlockAllocator(nb)
    held = []  # list of lists
    for do_alloc, n in ops:
        if do_alloc or not held:
            got = alloc.alloc(n)
            total_held = sum(len(h) for h in held)
            if n <= nb - 1 - total_held:
                assert got is not None and len(got) == n
                held.append(got)
            else:
                assert got is None  # all-or-nothing
        else:
            alloc.release(held.pop(0))  # sole owner: decref-to-zero + free
        flat = [b for h in held for b in h]
        assert len(flat) == len(set(flat))  # no block owned twice
        assert BlockAllocator.TRASH not in flat
        assert alloc.n_free + alloc.n_live == nb - 1
        assert alloc.n_live == len(flat)


def test_block_allocator_double_free_raises():
    alloc = BlockAllocator(6)
    a = alloc.alloc(2)
    alloc.release(a)
    with pytest.raises(ValueError):
        alloc.free(a)  # double free (no longer live)
    with pytest.raises(ValueError):
        alloc.free([99])  # foreign id
    b = alloc.alloc(5)
    assert b is not None and BlockAllocator.TRASH not in b
    assert alloc.alloc(1) is None
    with pytest.raises(ValueError):
        alloc.free(b)  # still referenced: strict free refuses owned blocks


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=2, max_value=13),
    st.lists(
        st.tuples(st.integers(min_value=0, max_value=3),
                  st.integers(min_value=0, max_value=5)),
        max_size=60,
    ),
)
def test_block_allocator_refcount_properties(nb, ops):
    """Random fork/share/free/evict interleavings over the refcounted
    allocator: no double-free, a referenced block is never freed, zeroed
    blocks stay live until explicitly freed (the cache-retention state),
    and draining all owners returns every block to the free stack."""
    alloc = BlockAllocator(nb)
    held = []  # list of lists: each inner list is one ownership reference
    retained = []  # refcount-0 blocks kept live (simulated cache entries)
    for op, n in ops:
        if op == 0 or not (held or retained):  # alloc
            got = alloc.alloc(n)
            in_use = len({b for h in held for b in h} | set(retained))
            if n <= nb - 1 - in_use:
                assert got is not None and len(got) == n
                for b in got:
                    assert alloc.refcount(b) == 1
                held.append(got)
            else:
                assert got is None  # all-or-nothing
        elif op == 1 and held:  # share: a second owner increfs the chain
            src = held[n % len(held)]
            alloc.incref(src)
            held.append(list(src))
        elif op == 2 and held:  # drop one reference
            h = held.pop(n % len(held))
            before = {b: alloc.refcount(b) for b in set(h)}
            zeroed = alloc.decref(h)
            for b in set(h):
                if before[b] == h.count(b):  # this was the last owner
                    assert b in zeroed or h.count(b) > 1
            for b in zeroed:
                assert alloc.refcount(b) == 0  # live but unowned
                with pytest.raises(ValueError):
                    alloc.free([b, b])  # double id in one free call
            retained.extend(dict.fromkeys(zeroed))
        elif retained:  # evict one retained block
            b = retained.pop(n % len(retained))
            alloc.free([b])
            with pytest.raises(ValueError):
                alloc.free([b])  # double free
        owned = {b for h in held for b in h}
        for h in held:
            for b in set(h):
                assert alloc.refcount(b) >= 1
        for b in retained:
            if b not in owned:
                assert alloc.refcount(b) == 0
        assert BlockAllocator.TRASH not in owned
        assert alloc.n_free + alloc.n_live == nb - 1
    # drain: releasing every reference then freeing retained blocks returns
    # the allocator to its initial all-free state
    for h in held:
        retained.extend(alloc.decref(h))
    for b in retained:
        assert alloc.refcount(b) == 0  # every refcount reached zero by drain
        alloc.free([b])
    assert alloc.n_live == 0
    assert alloc.n_free == nb - 1


@pytest.mark.parametrize("cfg", [DENSE, SSM, HYBRID], ids=["dense", "ssm", "hybrid"])
def test_paged_layout_discovery(cfg):
    """Leaves with a sequence axis are paged; recurrent state is not, and
    the discovered axes index the real batch/seq dims."""
    model = build_model(cfg)
    axes, seq_axes, paged = paged_layout(model, max_len=16)
    cache = jax.eval_shape(lambda: model.init_cache(3, 16))
    any_paged = False
    for leaf, ax, sq, pg in zip(jax.tree.leaves(cache), jax.tree.leaves(axes),
                                jax.tree.leaves(seq_axes), jax.tree.leaves(paged)):
        assert leaf.shape[ax] == 3
        if pg:
            any_paged = True
            assert leaf.shape[sq] == 16 and sq == ax + 1
    assert any_paged == (cfg.family != "ssm")


def test_block_pool_admit_free_roundtrip():
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=2, max_len=32, block_size=8, num_blocks=7)
    s0 = pool.admit(20)  # 3 blocks
    s1 = pool.admit(17)  # 3 blocks
    assert {s0, s1} == {0, 1}
    assert pool.blocks_in_use == 6 and pool.n_free_blocks == 0
    assert pool.admit(1) is None  # out of slots AND blocks
    assert not pool.fits(8)
    table0 = pool.block_table[s0].copy()
    assert (table0[:3] > 0).all() and (table0[3:] == 0).all()
    pool.free(s0)
    assert pool.blocks_in_use == 3 and pool.n_free_blocks == 3
    with pytest.raises(ValueError):
        pool.free(s0)  # not active
    s2 = pool.admit(24)
    assert s2 == s0 and pool.peak_blocks == 6


# -- chunked-prefill + paged decode parity ------------------------------------

STAGGERED = [(7, 5, 0), (6, 3, 1), (5, 6, 2)]


def test_paged_parity_dense_glass():
    eng = _assert_paged_parity(DENSE, GlassConfig(density=0.5), "compact", STAGGERED)
    # chunked prefill really ran multi-chunk (prompt 7 > chunk 3)
    assert eng.max_prefill_tokens_per_tick == 3


def test_paged_parity_dense_no_glass():
    _assert_paged_parity(DENSE, None, "compact", STAGGERED)


@pytest.mark.parametrize("mode", ["masked", "compact"])
def test_chunked_prefill_parity_moe_slow(mode):
    _assert_paged_parity(MOE, GlassConfig(density=0.5), mode, STAGGERED)


def test_chunked_prefill_parity_ssm_slow():
    _assert_paged_parity(SSM, GlassConfig(density=0.5), "masked", STAGGERED)


def test_chunked_prefill_parity_hybrid_slow():
    _assert_paged_parity(HYBRID, GlassConfig(density=0.5), "compact", STAGGERED)


def test_block_reuse_no_kv_leak_slow():
    """A tight pool (blocks for ~1.5 requests) forces every request to reuse
    the previous occupants' blocks; outputs must match fresh single-request
    serving, so no KV can leak through reused blocks."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    spec = [(8, 6, 0), (4, 3, 0), (6, 8, 0)]  # shrinking then growing footprints
    reqs = _requests(spec)
    eng = PagedEngine(model, params, max_slots=2, max_len=32, block_size=8,
                      num_blocks=4, chunk_tokens=4,
                      glass=GlassConfig(density=0.5), global_prior=prior)
    done = eng.run(reqs)
    assert eng.pool.peak_blocks <= 3
    ref = Engine(model, params, glass=GlassConfig(density=0.5), global_prior=prior)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")


def test_prefill_work_bounded_long_prompt():
    """A long prompt must be admitted in bounded chunks with decode ticks of
    a live request interleaved between them — bounded admission latency."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(3)
    short = Request(uid=0, prompt=rng.randint(3, 101, size=4).astype(np.int32),
                    max_new=12, arrival=0)
    long_ = Request(uid=1, prompt=rng.randint(3, 101, size=24).astype(np.int32),
                    max_new=3, arrival=2)
    eng = PagedEngine(model, params, max_slots=2, max_len=32, block_size=8,
                      chunk_tokens=4)
    done = eng.run([short, long_])
    assert eng.max_prefill_tokens_per_tick <= 4  # per-tick prefill work bound
    # the short request kept decoding during the 6 chunk ticks: it finished
    # well before a serial (prefill-long-first) schedule would allow
    assert done[0].finished_step <= short.arrival + 1 + short.max_new + 2
    ref = Engine(model, params)
    for r in (short, long_):
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens)
    # allocated-KV accounting: the paged pool integrated strictly less
    # memory over time than the always-fully-allocated slot arena would
    arena_row_ticks = eng.pool.max_slots * eng.pool.max_len * eng.t
    assert 0 < eng.kv_row_ticks < arena_row_ticks


# -- block-sparse decode path -------------------------------------------------


def test_block_sparse_rowwise_kernel_matches_oracle():
    from repro.kernels.ops import glass_ffn_rowwise

    rng = np.random.RandomState(0)
    B, d, m, bs = 4, 16, 128, 32
    x = jnp.asarray(rng.randn(B, d), jnp.float32)
    wu = jnp.asarray(rng.randn(d, m), jnp.float32)
    wd = jnp.asarray(rng.randn(m, d), jnp.float32)
    wg = jnp.asarray(rng.randn(d, m), jnp.float32)
    bidx = jnp.asarray([[0, 2], [1, 3], [0, 1], [2, 3]], jnp.int32)
    out = glass_ffn_rowwise(x, wu, wd, bidx, wg, act="silu", block_size=bs,
                            interpret=True)
    for b in range(B):
        mask = np.zeros(m, np.float32)
        for blk in np.asarray(bidx[b]):
            mask[blk * bs : (blk + 1) * bs] = 1.0
        h = np.asarray(jax.nn.silu(x[b] @ wg)) * np.asarray(x[b] @ wu) * mask
        np.testing.assert_allclose(out[b], h @ wd, rtol=2e-5, atol=2e-5)


def test_paged_block_sparse_matches_masked_slow():
    """block_sparse (pallas kernel on per-slot block lists) and masked
    (dense matmul times the same block mask) are the same function."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    gc = GlassConfig(density=0.5, selection="block", block_size=32)
    reqs = _requests(STAGGERED)
    outs = {}
    for mode in ("block_sparse", "masked"):
        eng = PagedEngine(model, params, max_slots=2, max_len=32, block_size=8,
                          chunk_tokens=3, glass=gc, global_prior=prior,
                          glass_mode=mode)
        outs[mode] = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(outs["block_sparse"][r.uid].tokens,
                                      outs["masked"][r.uid].tokens)


def test_block_sparse_rejects_bad_config():
    model = build_model(MOE)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    with pytest.raises(NotImplementedError):
        PagedEngine(model, params, glass=GlassConfig(density=0.5, selection="block"),
                    global_prior=_prior_for(MOE), glass_mode="block_sparse")
    dmodel = build_model(DENSE)
    with pytest.raises(ValueError):
        PagedEngine(dmodel, params, glass=GlassConfig(density=0.5),  # neuron selection
                    global_prior=_prior_for(DENSE), glass_mode="block_sparse")
    with pytest.raises(ValueError):
        # block selection yields block ids: gathering compact weights with
        # them would silently select the wrong units
        PagedEngine(dmodel, params,
                    glass=GlassConfig(density=0.5, selection="block", block_size=32),
                    global_prior=_prior_for(DENSE), glass_mode="compact")


# -- admission policies -------------------------------------------------------


def _policy_requests():
    reqs = [
        Request(uid=0, prompt=np.zeros(4, np.int32), max_new=4, priority=0),
        Request(uid=1, prompt=np.zeros(4, np.int32), max_new=4, priority=5,
                deadline=30),
        Request(uid=2, prompt=np.zeros(4, np.int32), max_new=4, priority=1,
                deadline=10),
    ]
    return reqs


def test_admission_policy_fifo():
    s = Scheduler(max_len=32, policy=AdmissionPolicy.FIFO)
    for r in _policy_requests():
        s.submit(r)
    assert [r.uid for r in s.pop_admissible(0, 3)] == [0, 1, 2]


def test_admission_policy_priority():
    s = Scheduler(max_len=32, policy=AdmissionPolicy.PRIORITY)
    for r in _policy_requests():
        s.submit(r)
    assert [r.uid for r in s.pop_admissible(0, 3)] == [1, 2, 0]


def test_admission_policy_deadline():
    s = Scheduler(max_len=32, policy=AdmissionPolicy.DEADLINE)
    for r in _policy_requests():
        s.submit(r)
    # EDF: uid2 (deadline 10), uid1 (30), uid0 (no deadline -> last)
    assert [r.uid for r in s.pop_admissible(0, 3)] == [2, 1, 0]


def test_run_validates_block_capacity():
    """run() must route through PagedEngine.submit's capacity check: an
    over-capacity request raises a ValueError naming the shortfall instead
    of spinning until the drain-budget RuntimeError."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    eng = PagedEngine(model, params, max_slots=1, max_len=32, block_size=8,
                      num_blocks=3, chunk_tokens=4)
    with pytest.raises(ValueError, match="blocks > pool capacity"):
        eng.run([Request(uid=0, prompt=np.zeros(20, np.int32), max_new=10)])


def test_admission_pop_never_compares_requests():
    """Regression: picking a non-head request must remove it by index, not
    by equality — deque.remove would invoke the dataclass __eq__, which
    compares the ndarray prompt and raises whenever two queued requests
    share a uid (e.g. a retried submission)."""
    s = Scheduler(max_len=32, policy=AdmissionPolicy.DEADLINE)
    s.submit(Request(uid=7, prompt=np.zeros(4, np.int32), max_new=4, deadline=50))
    s.submit(Request(uid=7, prompt=np.ones(4, np.int32), max_new=4, deadline=5))
    got = s.pop_admissible(0, 2)
    assert [r.deadline for r in got] == [5, 50]


def test_admission_fits_filter_skips_not_blocks():
    """A request that doesn't fit is skipped (stays queued), later smaller
    ones are admitted, and capacity consumed by a pick is visible to the
    next pick."""
    s = Scheduler(max_len=64, policy=AdmissionPolicy.FIFO)
    big = Request(uid=0, prompt=np.zeros(40, np.int32), max_new=8)
    small1 = Request(uid=1, prompt=np.zeros(4, np.int32), max_new=4)
    small2 = Request(uid=2, prompt=np.zeros(4, np.int32), max_new=4)
    for r in (big, small1, small2):
        s.submit(r)
    free = [14]  # free KV rows; each small request needs 7, big needs 47

    def fits(r):
        return len(r.prompt) + r.max_new - 1 <= free[0]

    got = []
    while True:
        picked = s.pop_admissible(0, 1, fits=fits)
        if not picked:
            break
        free[0] -= len(picked[0].prompt) + picked[0].max_new - 1
        got.append(picked[0].uid)
    assert got == [1, 2]  # big skipped, still queued
    assert [r.uid for r in s.queue] == [0]


def test_paged_engine_priority_order_slow():
    """With one slot, PRIORITY admission must serve the high-priority
    request first even though it was submitted last."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    reqs = [
        Request(uid=i, prompt=rng.randint(3, 101, size=4).astype(np.int32),
                max_new=3, priority=p)
        for i, p in enumerate([0, 0, 9])
    ]
    eng = PagedEngine(model, params, max_slots=1, max_len=16, block_size=8,
                      chunk_tokens=8, policy=AdmissionPolicy.PRIORITY)
    done = eng.run(reqs)
    assert done[2].finished_step < done[0].finished_step
    assert done[2].finished_step < done[1].finished_step


# -- Engine jit-cache invalidation --------------------------------------------


def test_engine_params_identity_evicts_jit_cache():
    model = build_model(DENSE)
    p1 = model.init(jax.random.key(0))
    p2 = model.init(jax.random.key(1))
    eng = Engine(model, p1)
    prompts = jnp.asarray(np.arange(4, dtype=np.int32))[None] + 3
    out1 = eng.generate(prompts, 4).tokens
    assert len(eng._jits) > 0
    eng.params = p2  # new identity -> cache must be evicted
    assert len(eng._jits) == 0
    out2 = eng.generate(prompts, 4).tokens
    fresh = Engine(model, p2).generate(prompts, 4).tokens
    np.testing.assert_array_equal(out2, fresh)
    assert not np.array_equal(out1, out2)  # different weights really served
    eng.params = p2  # same identity -> cache kept
    assert len(eng._jits) > 0
