"""Multi-device tests (8 forced host devices, subprocess-isolated):
shard-balanced compaction under shard_map, planner divisibility, compressed
DP training convergence, tiny-mesh dry-run lowering."""
import pytest

from tests.helpers import run_with_devices


def test_sharded_compaction_matches_unsharded():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.fusion import select_shard_balanced
from repro.sharding.dist_glass import compact_ffn_sharded, to_local_indices
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)
L, d, m, k = 3, 32, 64, 32
key = jax.random.key(0)
wu = jax.random.normal(key, (L, d, m))
wd = jax.random.normal(jax.random.fold_in(key, 1), (L, m, d))
wg = jax.random.normal(jax.random.fold_in(key, 2), (L, d, m))
scores = jax.random.normal(jax.random.fold_in(key, 3), (L, m))
idx, _ = select_shard_balanced(scores, k, 4)
idx_local = to_local_indices(idx, m, 4)
with mesh:
    comp = jax.jit(lambda *a: compact_ffn_sharded(mesh, {"w_up": a[0], "w_down": a[1], "w_gate": a[2]}, a[3]))(wu, wd, wg, idx_local)
# reference: plain gather with the same indices
ref_up = jnp.stack([jnp.take(wu[l], idx[l], axis=1) for l in range(L)])
ref_dn = jnp.stack([jnp.take(wd[l], idx[l], axis=0) for l in range(L)])
np.testing.assert_allclose(np.asarray(comp["w_up"]), np.asarray(ref_up), rtol=1e-6)
np.testing.assert_allclose(np.asarray(comp["w_down"]), np.asarray(ref_dn), rtol=1e-6)
print("COMPACT_OK")
""")
    assert "COMPACT_OK" in out


def test_planner_specs_divisible_all_archs():
    out = run_with_devices("""
import jax
import numpy as np
from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import param_specs
from repro.sharding.partition import Planner, _path_str
mesh = make_host_mesh(4, 2)
for arch in ASSIGNED:
    cfg = get_config(arch)
    shapes = param_specs(cfg)
    for mode in ("train", "prefill", "decode"):
        pl = Planner(cfg, mesh, mode=mode, fsdp=(mode == "train"))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            spec = pl.param_spec(_path_str(path), leaf.shape)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None: continue
                axes = (ax,) if isinstance(ax, str) else ax
                n = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % n == 0, (arch, _path_str(path), leaf.shape, spec)
print("PLANNER_OK")
""", timeout=900)
    assert "PLANNER_OK" in out


def test_compressed_dp_training_converges():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig, build_model
from repro.train.compress import init_residual, make_dp_train_step
from repro.train.optim import OptConfig, init_opt_state
mesh = make_host_mesh(8, 1)
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                  dtype="float32", remat="none")
model = build_model(cfg)
params = model.init(jax.random.key(0))
def loss_fn(p, batch):
    return model.loss(p, batch)[0]
oc = OptConfig(lr=3e-3, warmup_steps=0, total_steps=60, weight_decay=0.0)
results = {}
for compress in (False, True):
    p = params
    opt = init_opt_state(p)
    res = init_residual(p)
    step = make_dp_train_step(loss_fn, oc, mesh, compress=compress)
    key = jax.random.key(1)
    losses = []
    with mesh:
        for i in range(40):
            key, k2 = jax.random.split(key)
            toks = jax.random.randint(k2, (16, 32), 0, 64)
            batch = {"tokens": toks, "labels": toks}
            p, opt, res, m = step(p, opt, res, batch)
            losses.append(float(m["loss"]))
    results[compress] = losses
# both must converge; compressed within 10% of exact final loss
assert results[False][-1] < results[False][0] * 0.8
assert results[True][-1] < results[True][0] * 0.8
assert abs(results[True][-1] - results[False][-1]) / results[False][-1] < 0.10, results
print("COMPRESS_OK", round(results[False][-1], 3), round(results[True][-1], 3))
""", timeout=900)
    assert "COMPRESS_OK" in out


def test_dryrun_cell_on_tiny_mesh():
    """Full lower+compile of a tiny config through the real dry-run path."""
    out = run_with_devices("""
from pathlib import Path
from repro.configs import get_config, tiny_variant
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(4, 2)
cfg = tiny_variant(get_config("llama3-8b")).replace(dtype="bfloat16", remat="full")
for shp in ("train_4k", "prefill_32k", "decode_32k"):
    rec = run_cell(cfg, shp, mesh, Path("/tmp/dryrun_test_ci"))
    assert rec["hlo_flops_per_device"] > 0
print("DRYRUN_OK")
""", timeout=900)
    assert "DRYRUN_OK" in out
