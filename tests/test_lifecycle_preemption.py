"""Request-lifecycle state machine: incremental block allocation, preemption
with swap/recompute, and resume parity.

Token-identical greedy streams can hide serving-state corruption (argmax
absorbs small numeric damage), so the load-bearing tests here assert
STATE-LEVEL invariants:

  * swap-out -> swap-in restores the request's gathered KV block contents
    and recurrent-state rows BIT-identical (np equality, not allclose);
  * recompute replays the prompt through chunked prefill and reproduces the
    IDENTICAL fused GLASS mask (running sums over the same chunk
    boundaries), then re-feeds the generated prefix as forced tokens;
  * allocate-on-boundary never leaks or double-allocates blocks, keeps the
    block table consistent with the holdings, and admissions never breach
    the watermark reserve.

Token parity vs fresh single-request serving is asserted on top, for both
preemption kinds, across all four model families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.engine import Engine, PagedEngine
from repro.serve.kv_pool import BlockPool
from repro.serve.lifecycle import (
    Lifecycle,
    PreemptionConfig,
    ReqState,
    preemption_kind,
)
from repro.serve.scheduler import AdmissionPolicy, Request, Scheduler

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="lc-dense", family="dense", **BASE)
MOE = ModelConfig(name="lc-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="lc-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="lc-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})

FAMILIES = {
    "dense": (DENSE, "compact"),
    "moe": (MOE, "masked"),
    "rwkv6": (SSM, "masked"),
    "hybrid": (HYBRID, "compact"),
}


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _requests(spec, seed=0):
    rng = np.random.RandomState(seed)
    return [
        Request(uid=i, prompt=rng.randint(3, 101, size=l).astype(np.int32),
                max_new=n, arrival=a)
        for i, (l, n, a) in enumerate(spec)
    ]


def _request_device_state(pool: BlockPool, slot: int):
    """Host copy of everything the pool holds for ``slot``: its KV blocks
    (whole blocks, in table order) and its recurrent-state rows."""
    held = list(pool._held.get(slot, ()))
    out = []
    for leaf, ax, pg in zip(
        jax.tree.leaves(pool.cache), jax.tree.leaves(pool.axes),
        jax.tree.leaves(pool.paged),
    ):
        a = np.asarray(leaf)
        out.append(np.take(a, held, axis=ax) if pg else np.take(a, [slot], axis=ax))
    return out


def _glass_rows(eng: PagedEngine, slot: int):
    gs = eng.glass_slots
    if gs is None or gs.arena is None:
        return None
    ax = gs.slot_axis
    return [np.take(np.asarray(a), [slot], axis=ax) for a in jax.tree.leaves(gs.arena)]


def _step_until(eng, uid, state, min_outputs=0, limit=300):
    done = []
    for _ in range(limit):
        done += eng.step()
        e = eng.lc.entries.get(uid)
        if e is not None and e.state is state and len(e.outputs) >= min_outputs:
            return e, done
    raise AssertionError(f"uid {uid} never reached {state} with >= {min_outputs} outputs")


# -- lifecycle state machine --------------------------------------------------


def test_lifecycle_transition_legality():
    lc = Lifecycle()
    e = lc.add(Request(uid=0, prompt=np.zeros(4, np.int32), max_new=2))
    assert e.state is ReqState.WAITING
    with pytest.raises(ValueError, match="illegal transition"):
        lc.to(e, ReqState.RUNNING)  # must prefill first
    lc.to(e, ReqState.PREFILLING)
    with pytest.raises(ValueError, match="illegal transition"):
        lc.to(e, ReqState.SPECULATING)  # speculation is a RUNNING sub-phase
    # mid-prefill swap-out is the migration handoff: partial prefill travels
    # to another engine at a chunk boundary instead of being recomputed
    lc.to(e, ReqState.PREEMPTED_SWAPPED)
    with pytest.raises(ValueError, match="illegal transition"):
        lc.to(e, ReqState.PREFILLING)  # swapped resumes via swap-in only
    lc.to(e, ReqState.MIGRATING)
    lc.to(e, ReqState.PREFILLING)  # destination resumes the partial prefill
    lc.to(e, ReqState.RUNNING)
    lc.to(e, ReqState.PREEMPTED_SWAPPED)
    # swap-store cap overflow: the swapped store is dropped and the victim
    # degrades to recompute (counted as a degrade, not a new preemption)
    lc.to(e, ReqState.PREEMPTED_RECOMPUTE)
    lc.to(e, ReqState.PREFILLING)
    lc.to(e, ReqState.RUNNING)
    lc.to(e, ReqState.FINISHED)
    with pytest.raises(ValueError, match="illegal transition"):
        lc.to(e, ReqState.RUNNING)
    # duplicate live uid is rejected; a finished uid may be re-registered
    e2 = lc.add(Request(uid=0, prompt=np.zeros(4, np.int32), max_new=2))
    lc.to(e2, ReqState.PREFILLING)
    with pytest.raises(ValueError, match="already live"):
        lc.add(Request(uid=0, prompt=np.zeros(4, np.int32), max_new=2))
    assert lc.counts[("running", "preempted_swapped")] == 1
    assert lc.counts[("prefilling", "preempted_swapped")] == 1
    assert lc.counts[("preempted_swapped", "preempted_recompute")] == 1
    assert lc.preempted() == 2 and lc.preempted(kind="swap") == 2
    assert lc.preempted(kind="recompute") == 0  # degrade is not a new event


def test_submit_rejects_live_uid_allows_finished_reuse():
    """uids key the lifecycle entries: resubmitting an in-flight uid fails
    fast at submit(); a finished uid is pruned and reusable (so warmup +
    measured waves through one engine instance keep working)."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    eng = PagedEngine(model, params, max_slots=2, max_len=32, block_size=8,
                      chunk_tokens=4)
    r = Request(uid=3, prompt=np.arange(4, dtype=np.int32) + 3, max_new=3)
    eng.submit(r)
    with pytest.raises(ValueError, match="already in flight"):
        # duplicate while still QUEUED (no lifecycle entry exists yet)
        eng.submit(Request(uid=3, prompt=np.arange(6, dtype=np.int32) + 3, max_new=2))
    eng.step()  # now admitted and in flight
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(uid=3, prompt=np.arange(6, dtype=np.int32) + 3, max_new=2))
    done = eng.run()
    assert done[3].tokens.shape == (3,)
    assert 3 not in eng.lc.entries  # FINISHED entries are pruned
    done2 = eng.run([Request(uid=3, prompt=np.arange(4, dtype=np.int32) + 3,
                             max_new=2)])
    assert done2[3].tokens.shape == (2,)


def test_preemption_cost_model():
    cfg = PreemptionConfig(mode="auto", swap_cost_per_block=2.0,
                           recompute_cost_per_token=1.0)
    assert preemption_kind(cfg, blocks_held=2, tokens_to_replay=100) == "swap"
    assert preemption_kind(cfg, blocks_held=10, tokens_to_replay=3) == "recompute"
    assert preemption_kind(PreemptionConfig(mode="swap"), 100, 1) == "swap"
    assert preemption_kind(PreemptionConfig(mode="recompute"), 1, 100) == "recompute"
    with pytest.raises(ValueError):
        PreemptionConfig(mode="bogus")


def test_victim_selection_mirrors_admission_order():
    reqs = [
        Request(uid=0, prompt=np.zeros(4, np.int32), max_new=4, priority=5, deadline=10),
        Request(uid=1, prompt=np.zeros(4, np.int32), max_new=4, priority=1, deadline=50),
        Request(uid=2, prompt=np.zeros(4, np.int32), max_new=4, priority=3, deadline=None),
    ]
    for policy, want in [
        (AdmissionPolicy.FIFO, 2),      # newest submission yields first
        (AdmissionPolicy.PRIORITY, 1),  # lowest priority yields first
        (AdmissionPolicy.DEADLINE, 2),  # deadline-less = latest deadline
    ]:
        s = Scheduler(max_len=32, policy=policy)
        for r in reqs:
            s.submit(r)
        assert s.select_victim(reqs).uid == want, policy
    assert Scheduler(max_len=32).select_victim([]) is None


# -- allocate-on-boundary property tests --------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.integers(min_value=1, max_value=40)), max_size=30))
def test_boundary_allocation_properties(ops):
    """Random admit/grow/free interleavings on a tight pool: block holdings
    stay disjoint, the block table prefix mirrors the holdings, accounting
    balances, growth is all-or-nothing, and watermark-gated admissions
    never leave fewer than ``watermark`` free blocks."""
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=3, max_len=64, block_size=8,
                     num_blocks=9, watermark=2)
    rows: dict = {}  # slot -> rows currently ensured
    for op, arg in ops:
        if op == 0 and pool.n_free_slots:  # admit (watermark-gated)
            was_idle = not pool.active.any()
            if pool.fits_admission(arg):
                free0 = pool.n_free_blocks
                slot = pool.admit(arg)
                assert slot is not None
                rows[slot] = arg
                # never breached by an admission — except the liveness
                # waiver on an idle pool (nobody to preempt, so the
                # reserve must not starve a big first chunk)
                assert was_idle or pool.n_free_blocks >= pool.watermark
                assert free0 - pool.n_free_blocks == pool.blocks_needed(arg)
        elif op == 1 and rows:  # grow (may consume the reserve)
            slot = sorted(rows)[arg % len(rows)]
            target = min(rows[slot] + arg, pool.max_len)
            held0 = pool.held_blocks(slot)
            ok = pool.ensure_capacity(slot, target)
            if ok:
                rows[slot] = max(rows[slot], target)
                assert pool.held_blocks(slot) == pool.blocks_needed(rows[slot])
            else:  # all-or-nothing: a failed grow changes nothing
                assert pool.held_blocks(slot) == held0
        elif op == 2 and rows:  # free
            slot = sorted(rows)[arg % len(rows)]
            pool.free(slot)
            del rows[slot]
        # global invariants after every op
        flat = [b for s in rows for b in pool._held[s]]
        assert len(flat) == len(set(flat))  # no block owned twice
        assert 0 not in flat  # trash never handed out
        assert pool.allocator.n_free + pool.allocator.n_live == pool.num_blocks - 1
        assert pool.allocator.n_live == len(flat)
        for s in rows:  # table prefix == holdings, rest trash
            held = pool._held[s]
            assert list(pool.block_table[s, : len(held)]) == held
            assert (pool.block_table[s, len(held):] == 0).all()


def test_ensure_capacity_is_boundary_granular():
    """Growth allocates exactly one block per crossed boundary, never the
    full worst case."""
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=2, max_len=64, block_size=8, num_blocks=9)
    slot = pool.admit(4)  # first chunk: 1 block
    assert pool.held_blocks(slot) == 1
    assert pool.ensure_capacity(slot, 8) and pool.held_blocks(slot) == 1
    assert pool.ensure_capacity(slot, 9) and pool.held_blocks(slot) == 2
    assert pool.ensure_capacity(slot, 24) and pool.held_blocks(slot) == 3
    assert pool.ensure_capacity(slot, 6) and pool.held_blocks(slot) == 3  # shrink = no-op
    # exhaustion: all-or-nothing failure leaves holdings unchanged
    other = pool.admit(40)  # 5 blocks -> pool full
    assert pool.n_free_blocks == 0
    assert not pool.ensure_capacity(slot, 64)
    assert pool.held_blocks(slot) == 3
    pool.free(other)
    with pytest.raises(ValueError):
        pool.ensure_capacity(other, 8)  # inactive slot


# -- swap / recompute state parity (all four families) ------------------------


def _pressure_engine(cfg, mode, *, preemption, seed=1):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prior = _prior_for(cfg)
    glass = GlassConfig(density=0.5)
    eng = PagedEngine(model, params, max_slots=2, max_len=32, block_size=8,
                      chunk_tokens=3, glass=glass, global_prior=prior,
                      glass_mode=mode, preemption=preemption)
    ref = Engine(model, params, glass=glass, global_prior=prior, glass_mode=mode)
    return eng, ref


def _swap_roundtrip(cfg, mode):
    eng, ref = _pressure_engine(cfg, mode, preemption=PreemptionConfig(mode="swap"))
    reqs = _requests([(7, 8, 0), (5, 6, 0)])
    for r in reqs:
        eng.submit(r)
    e, early = _step_until(eng, 0, ReqState.RUNNING, min_outputs=2)
    slot = e.slot
    before = _request_device_state(eng.pool, slot)
    glass_before = _glass_rows(eng, slot)
    outputs_before = list(e.outputs)
    eng._preempt(e, "swap")
    assert e.state is ReqState.PREEMPTED_SWAPPED and e.slot == -1
    assert e.swap is not None and e.swap.nbytes > 0
    eng._swap_in_tick()
    assert e.state is ReqState.RUNNING and e.slot >= 0
    after = _request_device_state(eng.pool, e.slot)
    # STATE-level invariant: whole-block KV contents and recurrent-state
    # rows restored BIT-identical (block ids may differ; contents may not)
    assert len(before) == len(after)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)
    if glass_before is not None:
        for b, a in zip(glass_before, _glass_rows(eng, e.slot)):
            np.testing.assert_array_equal(b, a)
    assert e.outputs == outputs_before  # host progress untouched
    done = {f.uid: f for f in early}
    done.update(eng.run())  # drain the rest
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")
    assert eng.lc.preempted(kind="swap") >= 1


def _recompute_roundtrip(cfg, mode):
    eng, ref = _pressure_engine(cfg, mode, preemption=PreemptionConfig(mode="recompute"))
    reqs = _requests([(7, 8, 0), (5, 6, 0)])
    for r in reqs:
        eng.submit(r)
    e, _ = _step_until(eng, 0, ReqState.RUNNING, min_outputs=2)
    glass_before = _glass_rows(eng, e.slot)
    outputs_before = list(e.outputs)
    eng._preempt(e, "recompute")
    assert e.state is ReqState.PREEMPTED_RECOMPUTE and e.slot == -1
    assert e.outputs == outputs_before  # the prefix to replay
    e, early = _step_until(eng, 0, ReqState.RUNNING)
    # STATE-level invariant: the replayed chunked prefill (same chunk
    # boundaries over the same prompt tokens) rebuilt the IDENTICAL fused
    # GLASS mask — bit-equal rows, not argmax-equal tokens
    if glass_before is not None:
        for b, a in zip(glass_before, _glass_rows(eng, e.slot)):
            np.testing.assert_array_equal(b, a)
    # the step that resumed the request may already have decoded a forced
    # tick, so replay progress is bounded, and the recorded prefix is a
    # prefix of the stream — never re-appended, never diverged
    assert 0 <= e.replay_left <= len(outputs_before) - 1
    assert e.outputs[: len(outputs_before)] == outputs_before
    done = {f.uid: f for f in early}
    done.update(eng.run())
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")
    assert done[0].tokens.shape[0] == reqs[0].max_new
    assert list(done[0].tokens[: len(outputs_before)]) == outputs_before
    assert eng.lc.preempted(kind="recompute") >= 1
    assert eng.recompute_tokens > 0


def test_swap_roundtrip_state_parity_dense():
    _swap_roundtrip(*FAMILIES["dense"])


def test_recompute_roundtrip_mask_parity_dense():
    _recompute_roundtrip(*FAMILIES["dense"])


@pytest.mark.parametrize("family", ["moe", "rwkv6", "hybrid"])
def test_swap_roundtrip_state_parity_slow(family):
    _swap_roundtrip(*FAMILIES[family])


@pytest.mark.parametrize("family", ["moe", "rwkv6", "hybrid"])
def test_recompute_roundtrip_mask_parity_slow(family):
    _recompute_roundtrip(*FAMILIES[family])


# -- engine-driven preemption under pressure ----------------------------------


@pytest.mark.parametrize("kind", ["swap", "recompute", "auto"])
def test_pressure_parity_engine_driven_slow(kind):
    """A pool too small for the offered load: the engine must preempt on
    its own and every stream must still match fresh single-request
    serving exactly."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    glass = GlassConfig(density=0.5)
    rng = np.random.RandomState(3)
    reqs = [
        Request(uid=i, prompt=rng.randint(3, 101, size=8).astype(np.int32),
                max_new=10, arrival=0)
        for i in range(4)
    ]
    eng = PagedEngine(model, params, max_slots=3, max_len=32, block_size=8,
                      num_blocks=7, chunk_tokens=4, glass=glass,
                      global_prior=prior, preemption=PreemptionConfig(mode=kind))
    done = eng.run(reqs)
    assert eng.preempt_count > 0  # pressure really forced preemptions
    ref = Engine(model, params, glass=glass, global_prior=prior)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")


def test_watermark_waived_on_idle_pool_no_starvation():
    """Regression: a request whose first chunk + watermark exceed usable
    blocks must still be served once the pool is idle — the reserve exists
    to protect running requests, not to starve admission forever."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    # 2 usable blocks; chunk 16 -> first chunk needs 2 blocks; watermark 1
    eng = PagedEngine(model, params, max_slots=2, max_len=16, block_size=8,
                      num_blocks=3, chunk_tokens=16)
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(3, 101, size=16).astype(np.int32),
                    max_new=1, arrival=0) for i in range(2)]
    done = eng.run(reqs)  # would RuntimeError('did not drain') if starved
    assert sorted(done) == [0, 1]
    ref = Engine(model, params)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens)


def test_fits_accounts_watermark_and_swapins():
    """Satellite fix: the admission filter must reserve the watermark AND
    the blocks owed to swapped-out requests awaiting swap-in."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    eng = PagedEngine(model, params, max_slots=3, max_len=32, block_size=8,
                      num_blocks=8, chunk_tokens=4,
                      preemption=PreemptionConfig(mode="swap", watermark_blocks=1))
    r0 = Request(uid=0, prompt=np.arange(8, dtype=np.int32) + 3, max_new=12)
    eng.submit(r0)
    e, _ = _step_until(eng, 0, ReqState.RUNNING, min_outputs=1)
    eng._preempt(e, "swap")
    reserved = e.swap.n_blocks
    assert reserved > 0
    probe = Request(uid=1, prompt=np.arange(4, dtype=np.int32) + 3, max_new=4)
    probe._submit_seq = 999
    # first-chunk need (1 block) + watermark (waived while the pool is
    # idle) + swap reserve bound admission: the blocks owed to the swapped
    # request are never handed to a newcomer
    wm = eng.pool.watermark if eng.pool.active.any() else 0
    assert eng._fits(probe) == (1 + wm + reserved <= eng.pool.n_free_blocks)
    free = eng.pool.n_free_blocks
    assert free == eng.pool.num_blocks - 1  # everything was released by the swap
    # under full-need admission the same probe would check its static need
    eng.alloc_mode = "full"
    assert eng._fits(probe) == eng.pool.fits(len(probe.prompt) + probe.max_new - 1)


def test_incremental_admits_more_than_full_slow():
    """Acceptance: under arrival rate > capacity, incremental+preemption
    admits strictly more than full-need admission (lower admission waits,
    more requests in flight early) with zero token-stream divergence."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    glass = GlassConfig(density=0.5)
    rng = np.random.RandomState(5)
    reqs = [
        Request(uid=i, prompt=rng.randint(3, 101, size=8).astype(np.int32),
                max_new=12, arrival=0)
        for i in range(6)
    ]
    waits = {}
    outs = {}
    for mode in ("incremental", "full"):
        eng = PagedEngine(model, params, max_slots=4, max_len=32, block_size=8,
                          num_blocks=10, chunk_tokens=4, glass=glass,
                          global_prior=prior, alloc_mode=mode)
        outs[mode] = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival)
                              for r in reqs])
        waits[mode] = sorted(eng.admission_waits)
        if mode == "incremental":
            assert eng.preempt_count > 0
    # strictly more admitted per tick: every admission happens no later,
    # at least one strictly earlier
    assert all(i <= f for i, f in zip(waits["incremental"], waits["full"]))
    assert sum(waits["incremental"]) < sum(waits["full"])
    # and zero divergence for the preempted-and-resumed streams
    ref = Engine(model, params, glass=glass, global_prior=prior)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        for mode in ("incremental", "full"):
            np.testing.assert_array_equal(want, outs[mode][r.uid].tokens,
                                          err_msg=f"{mode} uid={r.uid}")


# -- shared-list kernel grouping ----------------------------------------------


def test_grouped_block_sparse_step_builder_matches_ungrouped():
    """launch.steps.make_decode_step_block_sparse(groups=...) — the dry-run
    builder for the shared-list batched decode — must agree exactly with
    the ungrouped (rowwise) builder on the same per-row block lists."""
    from repro.launch.steps import make_decode_step_block_sparse

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    B, L, nb = 3, DENSE.n_layers, 2
    cache = model.init_cache(B, 16)
    tok = jnp.asarray([[5], [5], [9]], jnp.int32)
    clen = jnp.zeros((B,), jnp.int32)
    # rows 0 and 1 share a block list (group of 2); row 2 differs
    bidx = jnp.asarray(
        [[[0, 2], [0, 2], [1, 2]], [[1, 0], [1, 0], [2, 0]]], jnp.int32
    )  # (L, B, nb)
    plain = make_decode_step_block_sparse(model, block_size=32)
    grouped = make_decode_step_block_sparse(model, block_size=32, groups=(2,))
    perm = jnp.asarray([0, 1, 2], jnp.int32)
    want, _ = plain(params, cache, tok, clen, bidx)
    got, _ = grouped(params, model.init_cache(B, 16), tok, clen, bidx, perm)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def _sampled_roundtrip(kind):
    """Satellite: a seeded sampled stream is token-identical through
    swap/recompute preemption vs an undisturbed engine — and not just
    tokens: the per-slot counter-based RNG position and every KV row the
    request owns match at the comparison point (argmax luck cannot hide
    state corruption when the stream is sampled)."""
    from tests.test_speculative_decode import _gathered_rows

    from repro.serve.sampling import SamplingParams

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    glass = GlassConfig(density=0.5)
    sp = SamplingParams(temperature=0.9, top_k=30, seed=2024)
    prompt = np.random.RandomState(9).randint(3, 101, size=7).astype(np.int32)

    def mk():
        return PagedEngine(model, params, max_slots=2, max_len=64, block_size=8,
                           chunk_tokens=3, glass=glass, global_prior=prior,
                           preemption=PreemptionConfig(mode=kind),
                           decode_chunk=1)

    churn = mk()
    uid = churn.add_request(prompt.copy(), 14, sampling=sp)
    e, _ = _step_until(churn, uid, ReqState.RUNNING, min_outputs=3)
    churn._preempt(e, kind)
    e, _ = _step_until(churn, uid, ReqState.RUNNING)
    # drive past any recompute replay so fresh sampled tokens follow churn
    guard = 0
    while e.replay_left or len(e.outputs) < 8:
        churn.step()
        guard += 1
        assert guard < 200 and uid in churn.lc.entries
    g, n = len(e.outputs), int(churn.pool.lengths[e.slot])
    assert e.rng_pos == g  # the PRNG counter tracks accepted tokens exactly
    base = mk()
    base.add_request(prompt.copy(), 14, sampling=sp, uid=uid)
    guard = 0
    while True:
        eb = base.lc.entries.get(uid)
        if eb is not None and eb.state is ReqState.RUNNING and len(eb.outputs) >= g:
            break
        base.step()
        guard += 1
        assert guard < 400
    # token stream, RNG counter, and KV rows all match the undisturbed run
    assert eb.outputs[:g] == e.outputs
    assert eb.rng_pos == len(eb.outputs)
    if len(eb.outputs) == g:
        for a, b in zip(_gathered_rows(churn.pool, e.slot, n),
                        _gathered_rows(base.pool, eb.slot, n)):
            np.testing.assert_array_equal(a, b)
    done = churn.run()
    done_base = base.run()
    np.testing.assert_array_equal(done_base[uid].tokens, done[uid].tokens)
    assert churn.lc.preempted(kind=kind) >= 1


@pytest.mark.sampling
def test_sampled_stream_deterministic_through_swap():
    _sampled_roundtrip("swap")


@pytest.mark.sampling
def test_sampled_stream_deterministic_through_recompute_slow():
    _sampled_roundtrip("recompute")


@pytest.mark.sampling
def test_sampled_pressure_parity_engine_driven_slow():
    """Sampled + greedy mixed load on a pool too small for it: organic
    preemption must leave every stream — sampled ones included —
    identical to fresh single-request serving."""
    from repro.serve.sampling import SamplingParams

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    glass = GlassConfig(density=0.5)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(3, 101, size=8).astype(np.int32) for _ in range(4)]
    sps = [None, SamplingParams(temperature=1.0, seed=7),
           SamplingParams(temperature=0.8, top_k=40, seed=8), None]

    def serve(eng, which):
        outs = {}
        for i in which:
            eng.add_request(prompts[i], 10, sampling=sps[i], uid=i)
        guard = 0
        while eng._work_remaining():
            guard += 1
            assert guard < 900
            for o in eng.step():
                if o.finished:
                    outs[o.uid] = o
        return outs

    eng = PagedEngine(model, params, max_slots=3, max_len=32, block_size=8,
                      num_blocks=7, chunk_tokens=4, glass=glass,
                      global_prior=prior, preemption=PreemptionConfig(mode="auto"))
    done = serve(eng, range(4))
    assert eng.preempt_count > 0
    for i in range(4):
        solo = PagedEngine(model, params, max_slots=3, max_len=32, block_size=8,
                           chunk_tokens=4, glass=glass, global_prior=prior)
        want = serve(solo, [i])[i]
        np.testing.assert_array_equal(want.tokens, done[i].tokens,
                                      err_msg=f"uid={i}")


def test_block_sparse_groups_identical_lists_slow():
    """Decode rows whose active-block lists coincide must batch through the
    shared-list glass_ffn kernel (grouped_rows telemetry) and stay
    token-identical to the masked reference; a row with a different list
    falls back to rowwise in the same tick."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    gc = GlassConfig(density=0.5, selection="block", block_size=32)
    rng = np.random.RandomState(0)
    shared_prompt = rng.randint(3, 101, size=6).astype(np.int32)
    other_prompt = rng.randint(3, 101, size=6).astype(np.int32)
    reqs = [
        Request(uid=0, prompt=shared_prompt.copy(), max_new=8, arrival=0),
        Request(uid=1, prompt=shared_prompt.copy(), max_new=8, arrival=0),
        Request(uid=2, prompt=other_prompt, max_new=8, arrival=0),
    ]
    outs = {}
    grouped = 0
    for mode in ("block_sparse", "masked"):
        eng = PagedEngine(model, params, max_slots=3, max_len=32, block_size=8,
                          chunk_tokens=3, glass=gc, global_prior=prior,
                          glass_mode=mode)
        outs[mode] = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival)
                              for r in reqs])
        if mode == "block_sparse":
            grouped = eng.grouped_rows
    assert grouped > 0  # the shared-list kernel really served live rows
    for r in reqs:
        np.testing.assert_array_equal(outs["block_sparse"][r.uid].tokens,
                                      outs["masked"][r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
