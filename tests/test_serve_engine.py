"""Continuous-batching engine: parity with single-request serving, GLASS
mode agreement, and slot-eviction hygiene.

The load-bearing property: for greedy decoding, the continuous engine must
be TOKEN-IDENTICAL to running each request alone through the static
``Engine`` — regardless of arrival staggering, slot reuse, queueing, or
which other requests share the arena.  That is what makes per-slot masking
(attention ``kv_len`` + per-slot GLASS state) trustworthy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.engine import ContinuousEngine, Engine
from repro.serve.kv_pool import KVPool, slot_axes
from repro.serve.scheduler import Request, Scheduler

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="srv-dense", family="dense", **BASE)
GEMMALIKE = DENSE.replace(name="srv-gemma", ffn_act="gelu", embed_scale=True,
                          logit_softcap=30.0)
MOE = ModelConfig(name="srv-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="srv-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="srv-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12,
                     **{**BASE, "n_layers": 4})


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _requests(spec, seed=0):
    """spec: list of (prompt_len, max_new, arrival)."""
    rng = np.random.RandomState(seed)
    return [
        Request(uid=i, prompt=rng.randint(3, 101, size=l).astype(np.int32),
                max_new=n, arrival=a)
        for i, (l, n, a) in enumerate(spec)
    ]


STAGGERED = [(4, 6, 0), (6, 4, 0), (4, 8, 1), (5, 1, 3), (6, 5, 7)]


def _assert_parity(cfg, glass, mode, spec=STAGGERED, max_slots=2):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prior = _prior_for(cfg) if glass else None
    reqs = _requests(spec)
    eng = ContinuousEngine(model, params, max_slots=max_slots, max_len=32,
                           glass=glass, global_prior=prior, glass_mode=mode)
    done = eng.run(reqs)
    ref = Engine(model, params, glass=glass, global_prior=prior, glass_mode=mode)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens, err_msg=f"uid={r.uid}")
    return eng


# -- parity: continuous == per-request single serving ------------------------


@pytest.mark.parametrize("mode", ["compact", "masked"])
def test_parity_dense_glass(mode):
    _assert_parity(DENSE, GlassConfig(density=0.5), mode)


def test_parity_dense_no_glass():
    eng = _assert_parity(DENSE, None, "compact")
    # continuous batching actually overlapped requests (not serial fallback)
    assert eng.t < sum(n for _, n, _ in STAGGERED)


def test_parity_gemmalike_glass():
    _assert_parity(GEMMALIKE, GlassConfig(density=0.5), "compact")


@pytest.mark.parametrize("mode", ["compact", "masked"])
def test_parity_moe_glass_slow(mode):
    _assert_parity(MOE, GlassConfig(density=0.5), mode, spec=[(4, 5, 0), (6, 3, 1), (5, 6, 2)])


def test_parity_ssm_glass_slow():
    _assert_parity(SSM, GlassConfig(density=0.5), "masked", spec=[(4, 5, 0), (6, 3, 1), (5, 6, 2)])


def test_parity_hybrid_glass_slow():
    _assert_parity(HYBRID, GlassConfig(density=0.5), "compact", spec=[(4, 5, 0), (6, 3, 1), (5, 6, 2)])


# -- glass_mode agreement ----------------------------------------------------


@pytest.mark.parametrize("cfg", [DENSE, MOE], ids=["dense", "moe"])
def test_compact_and_masked_agree(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prior = _prior_for(cfg)
    reqs = _requests([(4, 6, 0), (6, 4, 1), (5, 5, 2)])
    outs = {}
    for mode in ("compact", "masked"):
        eng = ContinuousEngine(model, params, max_slots=2, max_len=32,
                               glass=GlassConfig(density=0.5), global_prior=prior,
                               glass_mode=mode)
        outs[mode] = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(outs["compact"][r.uid].tokens,
                                      outs["masked"][r.uid].tokens)


# -- eviction / reuse hygiene -------------------------------------------------


def test_slot_eviction_no_kv_leak():
    """Every request through a single recycled slot must match a fresh
    engine serving only that request: the slot's previous occupant (longer
    prompts, longer generations) must be invisible."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    prior = _prior_for(DENSE)
    spec = [(8, 6, 0), (4, 3, 0), (6, 8, 0)]  # shrinking then growing footprints
    reqs = _requests(spec)
    eng = ContinuousEngine(model, params, max_slots=1, max_len=32,
                           glass=GlassConfig(density=0.5), global_prior=prior)
    done = eng.run(reqs)
    for r in reqs:
        fresh = ContinuousEngine(model, params, max_slots=1, max_len=32,
                                 glass=GlassConfig(density=0.5), global_prior=prior)
        alone = fresh.run([Request(uid=0, prompt=r.prompt, max_new=r.max_new)])
        np.testing.assert_array_equal(alone[0].tokens, done[r.uid].tokens)


def test_ssm_state_cleared_on_eviction():
    """Recurrent families keep per-slot *state*, not KV rows — eviction must
    fully reset it."""
    model = build_model(SSM)
    params = model.init(jax.random.key(0))
    reqs = _requests([(8, 5, 0), (5, 4, 0)])
    eng = ContinuousEngine(model, params, max_slots=1, max_len=32)
    done = eng.run(reqs)
    ref = Engine(model, params)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(want, done[r.uid].tokens)


# -- scheduler / pool units ---------------------------------------------------


def test_scheduler_fifo_and_arrivals():
    s = Scheduler(max_len=32)
    for r in _requests([(4, 4, 5), (4, 4, 0), (4, 4, 0)]):
        s.submit(r)
    # t=0: uid 0 has not arrived; 1 and 2 are FIFO-admissible
    got = s.pop_admissible(now=0, k=2)
    assert [r.uid for r in got] == [1, 2]
    assert len(s) == 1
    # uid 0 arrives at t=5
    assert s.pop_admissible(now=4, k=2) == []
    assert [r.uid for r in s.pop_admissible(now=5, k=2)] == [0]


def test_scheduler_rejects_infeasible():
    s = Scheduler(max_len=16)
    with pytest.raises(ValueError):
        s.submit(Request(uid=0, prompt=np.zeros(12, np.int32), max_new=6))
    with pytest.raises(ValueError):
        s.submit(Request(uid=1, prompt=np.zeros(4, np.int32), max_new=0))
    s.submit(Request(uid=2, prompt=np.zeros(12, np.int32), max_new=5))


@pytest.mark.parametrize("cfg", [DENSE, SSM, HYBRID], ids=["dense", "ssm", "hybrid"])
def test_kv_pool_slot_axis_discovery(cfg):
    model = build_model(cfg)
    axes = slot_axes(model, max_len=16)
    cache = jax.eval_shape(lambda: model.init_cache(3, 16))
    for leaf, ax in zip(jax.tree.leaves(cache), jax.tree.leaves(axes)):
        assert leaf.shape[ax] == 3  # the discovered axis really is the batch axis


def test_kv_pool_alloc_free_roundtrip():
    model = build_model(DENSE)
    pool = KVPool(model, max_slots=2, max_len=8)
    assert pool.n_free == 2
    s0, s1 = pool.alloc(), pool.alloc()
    assert {s0, s1} == {0, 1} and pool.alloc() is None
    _, cache, _ = model.prefill(model.init(jax.random.key(0)),
                                {"tokens": jnp.ones((1, 4), jnp.int32)}, 4)
    pool.write_prefill(s0, cache, 4)
    assert pool.active[s0] and pool.lengths[s0] == 4
    pool.free(s0)
    assert not pool.active[s0] and pool.lengths[s0] == 0 and pool.n_free == 1
    # freed row is zeroed
    assert float(jnp.abs(pool.cache["k"][:, s0]).max()) == 0.0
