"""Cross-layer integration: block-selection -> Pallas kernel, specs table,
roofline formatter, engine determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.configs import ASSIGNED, get_config
from repro.core.fusion import GlassConfig, glass_scores, select_blocks
from repro.kernels.glass_ffn import glass_ffn_block_sparse
from repro.launch.specs import SHAPES, applicable_shapes, compact_config
from repro.models.ffn import ffn_forward, init_ffn
from repro.models.common import ModelConfig


def test_block_selection_feeds_kernel():
    """GLASS block selection -> Pallas block-sparse kernel == masked dense FFN."""
    cfg = ModelConfig(d_model=128, d_ff=512, dtype="float32")
    p = init_ffn(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 128))
    local = jnp.abs(jax.random.normal(jax.random.key(2), (512,)))
    glob = jnp.abs(jax.random.normal(jax.random.key(3), (512,)))
    scores = glass_scores(local, glob, lam=0.5)
    bidx, mask = select_blocks(scores, k=256, block_size=128)
    out_kernel = glass_ffn_block_sparse(
        x, p["w_up"], p["w_down"], bidx, p["w_gate"], act="silu", block_size=128, interpret=True
    )
    out_masked = ffn_forward(p, x, cfg, mask=mask)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_masked), atol=2e-5, rtol=2e-5)


def test_applicable_shapes_policy():
    cells = 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        cells += len(shapes)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
    assert cells == 32  # 10 archs x 3 + 2 sub-quadratic long-context cells


def test_compact_config_divisibility():
    """50% compact widths stay shardable over the 16-wide model axis."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        dcfg = compact_config(cfg, 0.5)
        assert dcfg.d_ff == cfg.d_ff // 2
        assert dcfg.d_ff % 16 == 0, arch


def test_roofline_formatter(tmp_path):
    import json
    from benchmarks.roofline import fmt_table, load_records
    rec = {
        "arch": "x", "shape": "train_4k", "mesh": {"data": 16, "model": 16},
        "roofline_terms_s": {"compute_s": 1.0, "memory_s": 0.1, "collective_s": 2.0},
        "bottleneck": "collective_s", "useful_flops_ratio": 0.5,
        "memory": {"peak_bytes": 2 * 1024**3}, "fits_hbm_16g": True,
    }
    (tmp_path / "a.json").write_text(json.dumps(rec))
    out = fmt_table(load_records(tmp_path))
    assert "collective" in out and "0.50" in out
    csv = fmt_table(load_records(tmp_path), csv=True)
    assert csv.splitlines()[0].startswith("arch,")


@given(st.text(max_size=64))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip_property(s):
    from repro.data.tokenizer import decode, encode
    assert decode(encode(s)) == s
