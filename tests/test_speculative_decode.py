"""Self-speculative multi-token decode: tiered GLASS draft/verify + the
state-invariant rollback suite.

Greedy token parity with the non-speculative paged path is necessary but
NOT sufficient — argmax absorbs state corruption (see the memory of PR 3's
parity tests) — so the load-bearing tests here compare a speculative engine
against a never-speculated reference engine at the STATE level:

  * every logical KV row up to the accepted frontier is BIT-identical
    (np equality, not allclose), gathered through each engine's own block
    table so block-id assignment differences cannot mask corruption;
  * rows past the frontier inside held blocks are exactly zero (rejected
    speculative writes were un-scattered, not merely masked);
  * recurrent-state rows (rwkv6 state/shifts, hybrid ssm/conv) are
    BIT-identical after the pre-draft-carry fix-up replay;
  * block holdings equal ``blocks_needed(lengths)`` and the allocator free
    STACK (order included) matches the reference — reverse-order release
    means a rolled-back pool hands out identical block ids from here on;
  * the pool never leaks or double-frees across random accept lengths
    0..k and random mid-speculation preemption.

The CI lane runs this module twice: ``SPEC_GLASS_MODE=fused`` (per-slot
fused masks / compact weights) and ``SPEC_GLASS_MODE=block_sparse`` (the
dense family switches to block selection + the pallas block-sparse decode
kernel, whose draft/target active-block lists must nest).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core import GlassConfig, build_tiered_masks
from repro.models import ModelConfig, build_model
from repro.serve.engine import Engine, PagedEngine
from repro.serve.kv_pool import BlockPool
from repro.serve.lifecycle import ReqState
from repro.serve.scheduler import Request

pytestmark = pytest.mark.speculative

SPEC_LANE = os.environ.get("SPEC_GLASS_MODE", "fused")  # fused | block_sparse
# gather | paged_pallas — CI runs the serving suites under both; families
# without an attention KV pool (rwkv6) always take the gather default
ATTN_MODE = os.environ.get("ATTN_MODE", "gather")

BASE = dict(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
            d_ff=96, vocab_size=101, dtype="float32", remat="none")
DENSE = ModelConfig(name="sp-dense", family="dense", **BASE)
MOE = ModelConfig(name="sp-moe", family="moe", n_experts=4, n_experts_per_tok=2,
                  moe_strategy="dense", **BASE)
SSM = ModelConfig(name="sp-ssm", family="ssm", rwkv_headdim=12, **BASE)
HYBRID = ModelConfig(name="sp-hybrid", family="hybrid", attn_every=2,
                     ssm_state=16, mamba_headdim=12, **{**BASE, "n_layers": 4})

FAMILIES = {
    "dense": (DENSE, "compact"),
    "moe": (MOE, "masked"),
    "rwkv6": (SSM, "masked"),
    "hybrid": (HYBRID, "compact"),
}


def _family_setup(family):
    """(cfg, glass_mode, selection, ffn_block_size) under the active lane.
    The block_sparse lane reroutes the dense family through block selection
    + the pallas kernel; the other families keep their fused-mask modes."""
    cfg, mode = FAMILIES[family]
    sel, bsz = "neuron", 128
    if SPEC_LANE == "block_sparse" and cfg.family == "dense":
        mode, sel, bsz = "block_sparse", "block", 32
    return cfg, mode, sel, bsz


def _prior_for(cfg: ModelConfig):
    if cfg.family == "moe":
        shape = (cfg.n_layers, cfg.n_experts, cfg.d_ff)
    elif cfg.family == "hybrid":
        shape = (cfg.d_ff,)
    else:
        shape = (cfg.n_layers, cfg.d_ff)
    return jnp.abs(jax.random.normal(jax.random.key(7), shape))


def _glass(sel="neuron", bsz=128, draft_ratio=0.5, density=0.5):
    return GlassConfig(density=density, draft_ratio=draft_ratio,
                       selection=sel, block_size=bsz)


def _engines(family, *, spec_k, draft_ratio=0.5, max_slots=2, max_len=64,
             num_blocks=None, decode_chunk=8, seed=0):
    cfg, mode, sel, bsz = _family_setup(family)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    prior = _prior_for(cfg)
    glass = _glass(sel, bsz, draft_ratio)
    attn = ATTN_MODE if cfg.family != "ssm" else "gather"
    eng = PagedEngine(model, params, max_slots=max_slots, max_len=max_len,
                      block_size=8, num_blocks=num_blocks, chunk_tokens=4,
                      glass=glass, global_prior=prior, glass_mode=mode,
                      spec_k=spec_k, decode_chunk=decode_chunk, attn_mode=attn)
    return model, params, prior, glass, eng


def _reference(model, params, prior, glass, family):
    cfg, mode, sel, bsz = _family_setup(family)
    return Engine(model, params, glass=GlassConfig(density=glass.density,
                                                   selection=sel, block_size=bsz),
                  global_prior=prior, glass_mode=mode)


def _requests(spec, seed=0):
    rng = np.random.RandomState(seed)
    return [
        Request(uid=i, prompt=rng.randint(3, 101, size=l).astype(np.int32),
                max_new=n, arrival=a)
        for i, (l, n, a) in enumerate(spec)
    ]


def _gathered_rows(pool: BlockPool, slot: int, n: int):
    """Host copy of the slot's logical KV rows [0, n) gathered through ITS
    OWN block table, plus its recurrent-state rows — the block-assignment-
    agnostic view two engines must agree on bit-for-bit."""
    out = []
    bs = pool.block_size
    for leaf, ax, pg in zip(
        jax.tree.leaves(pool.cache), jax.tree.leaves(pool.axes),
        jax.tree.leaves(pool.paged),
    ):
        a = np.asarray(leaf)
        if pg:
            rows = [
                np.take(a, [int(pool.block_table[slot, r // bs])], axis=ax)
                .take([r % bs], axis=ax + 1)
                for r in range(n)
            ]
            out.append(np.concatenate(rows, axis=ax) if rows else a[0:0])
        else:
            out.append(np.take(a, [slot], axis=ax))
    return out


def _residue_is_zero(pool: BlockPool, slot: int, n: int) -> bool:
    """Rows past the frontier inside the slot's held blocks must be exactly
    zero — proof the rollback un-scattered rejected writes."""
    if not pool.has_paged:
        return True
    bs = pool.block_size
    cap = pool.held_blocks(slot) * bs
    for leaf, ax, pg in zip(
        jax.tree.leaves(pool.cache), jax.tree.leaves(pool.axes),
        jax.tree.leaves(pool.paged),
    ):
        if not pg:
            continue
        a = np.asarray(leaf)
        for r in range(n, cap):
            blk = int(pool.block_table[slot, r // bs])
            row = np.take(a, [blk], axis=ax).take([r % bs], axis=ax + 1)
            if row.any():
                return False
    return True


def _assert_allocator_balanced(pool: BlockPool):
    if not pool.has_paged:
        return
    held = [b for blocks in pool._held.values() for b in blocks]
    assert len(held) == len(set(held)), "block owned twice"
    assert 0 not in held, "trash block handed out"
    assert pool.allocator.n_live == len(held)
    assert pool.allocator.n_free + pool.allocator.n_live == pool.num_blocks - 1


# -- tiered mask construction -------------------------------------------------


@pytest.mark.parametrize("selection,bsz", [("neuron", 128), ("block", 32)])
def test_tiered_masks_nest_per_layer_per_slot(selection, bsz):
    """Draft-tier active units (block ids under selection='block') must be a
    SUBSET of the target tier's, per layer per slot — the nesting that makes
    the draft pass a strictly cheaper approximation and keeps block-sparse
    decode's active-block lists nested."""
    rng = np.random.RandomState(0)
    L, B, m = 3, 4, 128
    stats = {
        "sum_abs": jnp.asarray(rng.rand(B, L, m).astype(np.float32)),
        "count": jnp.asarray(np.full((B,), 17.0, np.float32)),
    }
    prior = jnp.abs(jax.random.normal(jax.random.key(3), (L, m)))
    gcfg = GlassConfig(density=0.5, draft_ratio=0.5, selection=selection,
                       block_size=bsz)
    tgt, dft = build_tiered_masks(stats, prior, gcfg, slot_axis=True)
    ti, di = np.asarray(tgt.idx), np.asarray(dft.idx)
    assert di.shape[-1] < ti.shape[-1]  # the draft tier really is smaller
    for l in range(L):
        for b in range(B):
            t_set = set(ti[l, b].tolist())
            d_set = set(di[l, b].tolist())
            assert d_set <= t_set, (selection, l, b, sorted(d_set - t_set))
    # masks nest too: everywhere the draft keeps a unit, the target does
    tm, dm = np.asarray(tgt.mask), np.asarray(dft.mask)
    assert np.all(tm[dm > 0.5] > 0.5)
    # both tiers ranked the IDENTICAL fused scores
    np.testing.assert_array_equal(np.asarray(tgt.scores), np.asarray(dft.scores))


def test_tiered_config_validation():
    with pytest.raises(ValueError, match="draft_ratio"):
        GlassConfig(draft_ratio=0.0)
    with pytest.raises(ValueError, match="draft_ratio"):
        GlassConfig(draft_ratio=1.5)
    with pytest.raises(ValueError, match="draft_ratio"):
        GlassConfig().draft_config()
    d = GlassConfig(density=0.5, draft_ratio=0.5).draft_config()
    assert d.density == 0.25 and d.draft_ratio is None
    with pytest.raises(ValueError, match="draft_ratio"):
        build_tiered_masks({}, None, GlassConfig())
    with pytest.raises(ValueError, match="draft_ratio"):
        PagedEngine(build_model(DENSE), build_model(DENSE).init(jax.random.key(0)),
                    max_len=32, glass=GlassConfig(density=0.5),
                    global_prior=_prior_for(DENSE), spec_k=2)


# -- model-level multi-token verify -------------------------------------------


def test_verify_steps_bitwise_matches_sequential():
    """Model.verify_steps must return the SAME greedy verdicts and leave the
    cache BIT-identical to T individual JITTED decode steps — the contract
    the engine-level rollback exactness rests on.  The reference steps must
    be jitted: verify_steps is inline-compiled (unrolled, never a scan body)
    precisely so it matches other inline-compiled programs bit-for-bit, and
    eager op-by-op dispatch fuses nothing so it sits outside that contract
    (the engine only ever runs jitted programs)."""
    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(3, 101, size=(1, 5)),
                       jnp.int32)
    _, cache0, _ = model.prefill(params, {"tokens": toks}, 16)
    feed = jnp.asarray(np.random.RandomState(1).randint(3, 101, size=(1, 4)),
                       jnp.int32)
    greedy, cache_v = jax.jit(
        lambda p, c, t: model.verify_steps(p, t, c, jnp.int32(5))
    )(params, cache0, feed)
    step = jax.jit(model.decode_step)
    cache_s = cache0
    seq = []
    for j in range(4):
        lg, cache_s = step(params, feed[:, j : j + 1], cache_s,
                           jnp.int32(5 + j))
        seq.append(int(jnp.argmax(lg[0, -1].astype(jnp.float32))))
    assert list(np.asarray(greedy)[0]) == seq
    for a, b in zip(jax.tree.leaves(cache_v), jax.tree.leaves(cache_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_make_verify_step_builder_masked():
    from repro.launch.steps import make_verify_step

    model = build_model(DENSE)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(3, 101, size=(1, 4)),
                       jnp.int32)
    _, cache, _ = model.prefill(params, {"tokens": toks}, 16)
    feed = jnp.asarray([[9, 11, 13]], jnp.int32)
    mask = jnp.ones((DENSE.n_layers, DENSE.d_ff), jnp.float32)
    verify = make_verify_step(model, glass_mode="masked")
    g_masked, _ = verify(params, cache, feed, jnp.int32(4), mask)
    plain = make_verify_step(model)
    g_plain, _ = plain(params, cache, feed, jnp.int32(4))
    # an all-ones mask is a no-op: both programs agree exactly
    np.testing.assert_array_equal(np.asarray(g_masked), np.asarray(g_plain))
    with pytest.raises(ValueError):
        make_verify_step(model, glass_mode="bogus")


# -- greedy token parity (speculative vs plain vs single-request) -------------


def _parity_case(family, spec_k=2, draft_ratio=0.5):
    model, params, prior, glass, eng = _engines(family, spec_k=spec_k,
                                                draft_ratio=draft_ratio,
                                                max_slots=2, max_len=64)
    reqs = _requests([(6, 10, 0), (5, 8, 0), (7, 6, 2)])
    done = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs])
    assert eng.spec_ticks > 0, "the speculative path never ran"
    t = eng.spec_telemetry
    assert 0.0 <= t["draft_acceptance_rate"] <= 1.0
    # every speculative slot-round emits its accepted drafts plus one bonus
    assert t["emitted_tokens"] == t["accepted_tokens"] + eng.spec_slot_ticks
    ref = _reference(model, params, prior, glass, family)
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(np.asarray(want), done[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
    if eng.pool.has_paged:
        assert eng.pool.allocator.n_live == 0  # drained clean
        _assert_allocator_balanced(eng.pool)


def test_spec_token_parity_dense():
    _parity_case("dense")


@pytest.mark.parametrize("family", ["moe", "rwkv6", "hybrid"])
def test_spec_token_parity_slow(family):
    _parity_case(family)


# -- bit-identical state invariants vs a never-speculated engine ---------------


def _force_rollback_round(eng, e):
    """One speculative round whose first draft proposal is corrupted on the
    host.  ANY token id is a legal draft proposal, so the target tier must
    reject at position 0 and the rollback machinery (state fix-up,
    un-scatter, shrink) must erase the round — deterministically, instead
    of hoping the draft tier disagrees on a tiny random-init model (rwkv6's
    channel-mix barely moves the argmax there, so organic acceptance can
    be 100%)."""
    before = eng.spec_rollbacks
    for bump in (1, 2, 3):  # retry iff the corrupted token WAS the verdict
        run = [e]
        k = eng._spec_possible(run)
        k = eng._spec_capacity(run, k)
        assert k >= 1
        eng._spec_draft(run, k)
        ck = e.spec_ckpt
        e.outputs[ck.out_len] = (e.outputs[ck.out_len] + bump) % 101
        eng._spec_verify(run, k, [])
        assert e.state is ReqState.RUNNING
        if eng.spec_rollbacks > before:
            return
    raise AssertionError("a corrupted draft was accepted three times")


def _state_invariant_case(family, *, spec_k=3, draft_ratio=0.2, max_new=48,
                          spec_steps=8):
    """Drive a speculative engine, force at least one rejected round, then
    drive a fresh never-speculated engine (decode_chunk=1 so it can stop at
    the exact same progress) and compare EVERYTHING the pool holds for the
    request."""
    model, params, prior, glass, spec = _engines(family, spec_k=spec_k,
                                                 draft_ratio=draft_ratio,
                                                 max_slots=2, max_len=64)
    _, _, _, _, base = _engines(family, spec_k=0, draft_ratio=draft_ratio,
                                max_slots=2, max_len=64, decode_chunk=1)
    prompt = np.random.RandomState(1).randint(3, 101, size=6).astype(np.int32)
    spec.submit(Request(uid=0, prompt=prompt.copy(), max_new=max_new))
    for _ in range(spec_steps):
        spec.step()
        if 0 not in spec.lc.entries:
            break
    e = spec.lc.entries.get(0)
    assert e is not None, "request finished before the comparison point; " \
        "raise max_new or lower spec_steps"
    assert e.state is ReqState.RUNNING
    _force_rollback_round(spec, e)
    assert spec.spec_rollbacks > 0
    g, n = len(e.outputs), int(spec.pool.lengths[e.slot])
    base.submit(Request(uid=0, prompt=prompt.copy(), max_new=max_new))
    for _ in range(400):
        eb = base.lc.entries.get(0)
        if eb is not None and eb.state is ReqState.RUNNING and len(eb.outputs) >= g:
            break
        base.step()
    eb = base.lc.entries[0]
    assert len(eb.outputs) == g
    # token stream: necessary, not sufficient
    assert eb.outputs == e.outputs
    assert int(base.pool.lengths[eb.slot]) == n
    # STATE level: every logical KV row + recurrent-state row bit-identical
    for a, b in zip(_gathered_rows(spec.pool, e.slot, n),
                    _gathered_rows(base.pool, eb.slot, n)):
        np.testing.assert_array_equal(a, b)
    # rejected speculative writes were un-scattered, not merely masked
    assert _residue_is_zero(spec.pool, e.slot, n)
    if spec.pool.has_paged:
        # holdings exact, accounting balanced, and the free STACK matches
        # the never-speculated engine's (reverse-order release) — identical
        # block ids get handed out from here on
        assert spec.pool.held_blocks(e.slot) == spec.pool.blocks_needed(n)
        _assert_allocator_balanced(spec.pool)
        assert spec.pool.allocator._free == base.pool.allocator._free
        assert spec.pool._held[e.slot] == base.pool._held[eb.slot]
    # GLASS target rows of the slot agree (same stats, same prior)
    gs, gb = spec.glass_slots, base.glass_slots
    ax = gs.slot_axis
    for a, b in zip(jax.tree.leaves(gs.arena), jax.tree.leaves(gb.arena)):
        np.testing.assert_array_equal(
            np.take(np.asarray(a), [e.slot], axis=ax),
            np.take(np.asarray(b), [eb.slot], axis=ax),
        )


def test_spec_state_invariants_dense():
    _state_invariant_case("dense")


@pytest.mark.parametrize("family", ["rwkv6", "hybrid"])
def test_spec_state_invariants_slow(family):
    # rwkv6 accepts aggressively on random weights; a harsher draft tier
    # (draft_ratio 0.1) keeps rollbacks happening within the window
    _state_invariant_case(family, draft_ratio=0.1, max_new=56, spec_steps=6)


# -- mid-speculation preemption: the requeue footgun --------------------------


def _enter_speculation(eng, uid):
    """Drive until RUNNING with some progress, then run ONLY the draft half
    of a speculative round — the engine is now frozen mid-speculation."""
    for _ in range(200):
        eng.step()
        e = eng.lc.entries.get(uid)
        if e is not None and e.state is ReqState.RUNNING and len(e.outputs) >= 2:
            break
    else:
        raise AssertionError("never reached RUNNING")
    run = [e]
    k = eng._spec_possible(run)
    assert k > 0
    k = eng._spec_capacity(run, k)
    assert k > 0
    eng._spec_draft(run, k)
    assert e.state is ReqState.SPECULATING and e.spec_len == k
    return e, k


@pytest.mark.parametrize("kind", ["recompute", "swap"])
def test_midspec_preemption_slices_speculated_tokens(kind):
    """Regression (the requeue footgun): preempting a mid-speculation victim
    must slice the provisional draft tokens off ``outputs`` BEFORE the
    recompute requeue (which replays outputs as forced tokens) or the swap
    capture — and the resumed stream must match single-request serving
    exactly."""
    model, params, prior, glass, eng = _engines("dense", spec_k=3,
                                                draft_ratio=0.2, max_len=64)
    r = _requests([(6, 12, 0)])[0]
    eng.submit(r)
    e, k = _enter_speculation(eng, 0)
    out_before = list(e.outputs[: -k])
    rows_before = e.spec_ckpt.rows
    eng._preempt(e, kind)
    # the provisional (unverified) tokens are GONE from the resume state
    assert e.outputs == out_before
    assert e.spec_len == 0 and e.spec_ckpt is None
    if kind == "recompute":
        assert e.state is ReqState.PREEMPTED_RECOMPUTE
        # the forced-token replay will re-feed exactly the accepted prefix
        assert all(q is e.req for q in eng.scheduler.queue)
    else:
        assert e.state is ReqState.PREEMPTED_SWAPPED
        # the swap captured the rolled-back footprint, not speculative growth
        assert e.swap.n_blocks == eng.pool.blocks_needed(rows_before)
    _assert_allocator_balanced(eng.pool)
    done = eng.run()
    ref = _reference(model, params, prior, glass, "dense")
    want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
    np.testing.assert_array_equal(np.asarray(want), done[0].tokens)
    assert eng.pool.allocator.n_live == 0
    assert eng.spec_rollbacks > 0


def test_midspec_finish_is_illegal():
    """A SPECULATING entry cannot jump straight to FINISHED — the lifecycle
    forces the engine through rollback/commit (back to RUNNING) first."""
    model, params, prior, glass, eng = _engines("dense", spec_k=3,
                                                draft_ratio=0.2, max_len=64)
    eng.submit(_requests([(6, 12, 0)])[0])
    e, _ = _enter_speculation(eng, 0)
    with pytest.raises(ValueError, match="illegal transition"):
        eng.lc.to(e, ReqState.FINISHED)
    with pytest.raises(ValueError, match="illegal transition"):
        eng.lc.to(e, ReqState.PREEMPTED_RECOMPUTE)
    eng._rollback_speculation(e)
    assert e.state is ReqState.RUNNING
    done = eng.run()
    ref = _reference(model, params, prior, glass, "dense")
    want = ref.generate(jnp.asarray(done[0].prompt)[None], 12).tokens[0]
    np.testing.assert_array_equal(np.asarray(want), done[0].tokens)


def test_spec_full_alloc_mode_keeps_reservation():
    """Regression: under ``alloc_mode="full"`` admission reserves the whole
    footprint and NOTHING re-allocates later, so a speculative rollback must
    not shrink the holding — shrinking freed reserved blocks and zeroed
    their table entries, sending every later KV write to the trash block
    (streams diverged from the non-speculative full-mode engine)."""
    cfg, mode, sel, bsz = _family_setup("dense")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prior = _prior_for(cfg)
    reqs = _requests([(6, 24, 0), (5, 20, 0)])
    outs = {}
    for spec_k in (0, 2):
        eng = PagedEngine(model, params, max_slots=2, max_len=64, block_size=8,
                          chunk_tokens=4, glass=_glass(sel, bsz, 0.2),
                          global_prior=prior, glass_mode=mode,
                          alloc_mode="full", spec_k=spec_k)
        outs[spec_k] = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival)
                                for r in reqs])
        if spec_k:
            assert eng.spec_rollbacks > 0  # rollback really exercised
            assert eng.pool.allocator.n_live == 0
    for r in reqs:
        np.testing.assert_array_equal(outs[0][r.uid].tokens,
                                      outs[2][r.uid].tokens,
                                      err_msg=f"uid={r.uid}")


# -- pool-level rollback property test ----------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=4),
                          st.integers(min_value=0, max_value=5)),
                max_size=20))
def test_spec_rollback_pool_property(ops):
    """Random speculative rounds at the pool level: ensure k+1 rows of
    growth, write garbage into the speculative rows, accept a random prefix
    (0..k), roll the rest back.  The pool must never leak or double-free,
    holdings must track the accepted frontier exactly, and rolled-back rows
    must read back zero."""
    model = build_model(DENSE)
    pool = BlockPool(model, max_slots=2, max_len=64, block_size=8, num_blocks=9)
    slot = pool.admit(4)
    pool.lengths[slot] = 4
    free_stack0 = list(pool.allocator._free)
    n = 4
    for k_raw, a_raw in ops:
        k = k_raw
        a = min(a_raw, k)
        if n + k + 1 > pool.max_len:
            break
        if not pool.ensure_capacity(slot, n + k + 1):
            break
        # scribble into every speculative row (draft + verify writes)
        pages = [int(pool.block_table[slot, r // 8]) for r in range(n, n + k + 1)]
        offs = [r % 8 for r in range(n, n + k + 1)]
        def scribble(leaf, ax, pg):
            if not pg:
                return leaf
            idx = (slice(None),) * ax + (np.asarray(pages), np.asarray(offs))
            return leaf.at[idx].set(7.0)
        pool.cache = jax.tree.map(scribble, pool.cache, pool.axes, pool.paged)
        # accept a, reject the rest
        pool.rollback_rows(slot, n + a + 1, n + k + 1)
        pool.shrink_to(slot, n + a + 1)
        n = n + a + 1
        pool.lengths[slot] = n
        assert pool.held_blocks(slot) == pool.blocks_needed(n)
        _assert_allocator_balanced(pool)
        assert _residue_is_zero(pool, slot, n)
    # full rollback to the start: the free stack returns to its exact
    # pre-speculation order (reverse-order release), so a parallel
    # never-speculated pool would hand out identical ids
    pool.rollback_rows(slot, 4, n)
    pool.shrink_to(slot, 4)
    pool.lengths[slot] = 4
    assert pool.held_blocks(slot) == pool.blocks_needed(4)
    assert pool.allocator._free == free_stack0
    with pytest.raises(ValueError):
        pool.rollback_rows(1 - slot, 0, 1)  # inactive slot
    with pytest.raises(ValueError):
        pool.shrink_to(1 - slot, 0)


# -- sampled speculation: positional verdicts under SamplingParams ------------


def _sampled_sp(seed=4242):
    from repro.serve.sampling import SamplingParams

    return SamplingParams(temperature=0.9, top_k=40, seed=seed)


@pytest.mark.sampling
def test_sampled_spec_token_parity():
    """Satellite: under per-request sampling, the target verdict is the
    counter-based positional sample from the pre-override logits — so a
    speculating engine's sampled streams are token-identical to a
    non-speculative engine's (speculation invisible under sampling, the
    same contract as greedy)."""
    model, params, prior, glass, spec = _engines("dense", spec_k=2,
                                                 draft_ratio=0.5)
    _, _, _, _, base = _engines("dense", spec_k=0, draft_ratio=0.5)
    rng = np.random.RandomState(11)
    prompts = [rng.randint(3, 101, size=6).astype(np.int32) for _ in range(3)]

    def serve(eng, spec_on):
        from repro.core import GlassParams

        outs = {}
        for i, p in enumerate(prompts):
            eng.add_request(p.copy(), 10, uid=i, sampling=_sampled_sp(100 + i),
                            glass=GlassParams(spec_k=2 if spec_on else 0))
        guard = 0
        while eng._work_remaining():
            guard += 1
            assert guard < 600
            for o in eng.step():
                if o.finished:
                    outs[o.uid] = o
        return outs

    got = serve(spec, True)
    assert spec.spec_ticks > 0, "the speculative path never ran"
    assert spec.spec_accepted > 0, "sampled drafts never matched the verdict"
    want = serve(base, False)
    for i in range(3):
        np.testing.assert_array_equal(want[i].tokens, got[i].tokens,
                                      err_msg=f"uid={i}")
    _assert_allocator_balanced(spec.pool)
    assert spec.pool.allocator.n_live == 0


@pytest.mark.sampling
def test_sampled_spec_state_invariants():
    """Seeded sampled stream + forced rollback rounds: the pool must be
    bit-identical to a never-speculated engine serving the same sampled
    request — KV rows, residue, holdings, free stack, AND the per-slot
    RNG counter (provisional drafts never advance it; rollback rewinds
    it with the outputs)."""
    model, params, prior, glass, spec = _engines("dense", spec_k=3,
                                                 draft_ratio=0.2, max_len=64)
    _, _, _, _, base = _engines("dense", spec_k=0, draft_ratio=0.2,
                                max_len=64, decode_chunk=1)
    prompt = np.random.RandomState(21).randint(3, 101, size=6).astype(np.int32)
    uid = spec.add_request(prompt.copy(), 48, sampling=_sampled_sp())
    for _ in range(8):
        spec.step()
        if uid not in spec.lc.entries:
            break
    e = spec.lc.entries.get(uid)
    assert e is not None and e.state is ReqState.RUNNING
    assert e.rng_pos == len(e.outputs)
    _force_rollback_round(spec, e)
    assert spec.spec_rollbacks > 0
    assert e.rng_pos == len(e.outputs)  # rollback rewound the counter too
    g, n = len(e.outputs), int(spec.pool.lengths[e.slot])
    base.add_request(prompt.copy(), 48, sampling=_sampled_sp(), uid=uid)
    for _ in range(400):
        eb = base.lc.entries.get(uid)
        if eb is not None and eb.state is ReqState.RUNNING and len(eb.outputs) >= g:
            break
        base.step()
    eb = base.lc.entries[uid]
    assert len(eb.outputs) == g
    assert eb.outputs == e.outputs  # sampled tokens, not argmax luck
    assert eb.rng_pos == e.rng_pos == g
    assert int(base.pool.lengths[eb.slot]) == n
    for a, b in zip(_gathered_rows(spec.pool, e.slot, n),
                    _gathered_rows(base.pool, eb.slot, n)):
        np.testing.assert_array_equal(a, b)
    assert _residue_is_zero(spec.pool, e.slot, n)
    if spec.pool.has_paged:
        assert spec.pool.held_blocks(e.slot) == spec.pool.blocks_needed(n)
        _assert_allocator_balanced(spec.pool)
        assert spec.pool.allocator._free == base.pool.allocator._free


@pytest.mark.sampling
def test_sampled_midspec_preemption_slices_and_resumes():
    """Mid-speculation preemption of a SAMPLED request: provisional draft
    tokens are sliced off, and the resumed stream still matches the
    undisturbed non-speculative engine (counter-based draws survive the
    recompute replay)."""
    model, params, prior, glass, eng = _engines("dense", spec_k=3,
                                                draft_ratio=0.2, max_len=64)
    prompt = np.random.RandomState(31).randint(3, 101, size=6).astype(np.int32)
    uid = eng.add_request(prompt.copy(), 12, sampling=_sampled_sp(9))
    e, k = _enter_speculation(eng, uid)
    out_before = list(e.outputs[:-k])
    eng._preempt(e, "recompute")
    assert e.outputs == out_before
    assert e.rng_pos == len(e.outputs)
    done = eng.run()
    _, _, _, _, base = _engines("dense", spec_k=0, draft_ratio=0.2, max_len=64)
    base.add_request(prompt.copy(), 12, sampling=_sampled_sp(9), uid=uid)
    want = base.run()
    np.testing.assert_array_equal(want[uid].tokens, done[uid].tokens)
    assert eng.pool.allocator.n_live == 0
    assert eng.spec_rollbacks > 0


# -- engine-driven stress: speculation + pressure preemption ------------------


def test_spec_under_pressure_parity_slow():
    """A pool too small for the offered load with speculation ON: organic
    preemption interleaves with speculative rounds (the capacity hunt may
    shrink k or evict a victim) and every stream must still match fresh
    single-request serving exactly, with the pool accounting clean."""
    model, params, prior, glass, eng = _engines(
        "dense", spec_k=3, draft_ratio=0.2, max_slots=3, max_len=32,
        num_blocks=6,
    )
    rng = np.random.RandomState(3)
    reqs = [
        Request(uid=i, prompt=rng.randint(3, 101, size=8).astype(np.int32),
                max_new=10, arrival=0)
        for i in range(4)
    ]
    done = eng.run(reqs)
    assert eng.preempt_count > 0  # pressure really forced preemptions
    assert eng.spec_ticks > 0  # and speculation really ran
    ref = _reference(model, params, prior, glass, "dense")
    for r in reqs:
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(np.asarray(want), done[r.uid].tokens,
                                      err_msg=f"uid={r.uid}")
    assert eng.pool.allocator.n_live == 0
    _assert_allocator_balanced(eng.pool)


def test_midspec_preemption_random_seeds_never_leak_slow():
    """Property-style: across seeds, freeze the engine mid-speculation,
    preempt with a random kind, drain, and assert parity + zero leaks."""
    for seed in range(3):
        kind = ["recompute", "swap"][seed % 2]
        model, params, prior, glass, eng = _engines(
            "dense", spec_k=2 + seed % 2, draft_ratio=0.2, max_len=64,
            seed=seed,
        )
        r = Request(uid=0,
                    prompt=np.random.RandomState(seed).randint(
                        3, 101, size=5 + seed).astype(np.int32),
                    max_new=11)
        eng.submit(r)
        e, _ = _enter_speculation(eng, 0)
        eng._preempt(e, kind)
        _assert_allocator_balanced(eng.pool)
        done = eng.run()
        ref = _reference(model, params, prior, glass, "dense")
        want = ref.generate(jnp.asarray(r.prompt)[None], r.max_new).tokens[0]
        np.testing.assert_array_equal(np.asarray(want), done[0].tokens,
                                      err_msg=f"seed={seed} kind={kind}")
        assert eng.pool.allocator.n_live == 0
