"""Optimizer + schedule + clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optim import OptConfig, adamw_update, clip_by_global_norm, init_opt_state, schedule


def test_adamw_minimizes_quadratic():
    oc = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200, clip_norm=10.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, g, opt, oc)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_clip_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 20.0
    np.testing.assert_allclose(np.asarray(clipped["a"]), 0.5, rtol=1e-5)


def test_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(oc, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert lrs[99] < lrs[50] < lrs[11]  # cosine decay
    assert lrs[99] >= 0.1 - 1e-6
