"""Pallas kernel allclose vs jnp oracles (interpret=True) + shape/dtype sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.glass_ffn import glass_ffn_block_sparse
from repro.kernels.local_stats import local_stats
from repro.kernels.ref import flash_attention_ref, glass_ffn_ref, local_stats_ref

KEY = jax.random.key(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,d,m,bs,act,gated", [
    (4, 128, 512, 128, "silu", True),
    (8, 256, 1024, 128, "gelu", True),
    (1, 128, 512, 256, "relu2", False),
    (16, 64, 256, 128, "relu", True),
])
def test_glass_ffn_sweep(B, d, m, bs, act, gated, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, d), dtype)
    wu = (jax.random.normal(ks[1], (d, m), jnp.float32) * 0.05).astype(dtype)
    wg = (jax.random.normal(ks[2], (d, m), jnp.float32) * 0.05).astype(dtype) if gated else None
    wd = (jax.random.normal(ks[3], (d, m // bs and d) if False else (m, d), jnp.float32) * 0.05).astype(dtype)
    nb = m // bs
    bidx = jnp.sort(jax.random.choice(ks[4], nb, (max(1, nb // 2),), replace=False)).astype(jnp.int32)
    out = glass_ffn_block_sparse(x, wu, wd, bidx, wg, act=act, block_size=bs, interpret=True)
    ref = glass_ffn_ref(x, wu, wd, bidx, wg, act=act, block_size=bs)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@given(
    st.sampled_from([64, 128, 256]),
    st.sampled_from([32, 64]),
    st.booleans(),
    st.sampled_from([None, 32]),
    st.sampled_from([None, 30.0]),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(S, hd, causal, window, softcap):
    B, H = 2, 2
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap,
                        block_q=32, block_k=32, interpret=True)
    r = flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_cross_lengths(dtype):
    """Sq != Skv (e.g. chunked prefill against a longer kv)."""
    B, H, Sq, Skv, hd = 1, 2, 64, 128, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, Skv, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, Skv, hd), dtype)
    o = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    r = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("T,m,bt,bm", [(256, 512, 64, 128), (128, 1024, 128, 256), (512, 256, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_local_stats_sweep(T, m, bt, bm, dtype):
    h = jax.random.normal(jax.random.fold_in(KEY, T + m), (T, m), dtype)
    s = local_stats(h, block_t=bt, block_m=bm, interpret=True)
    r = local_stats_ref(h)
    np.testing.assert_allclose(np.asarray(s), np.asarray(r), atol=1e-3, rtol=1e-3)


def test_ops_jit_wrappers():
    """The jit'd ops layer dispatches with static flags and interpret default."""
    from repro.kernels import flash_attention as fa_op
    from repro.kernels import glass_ffn as gf_op
    from repro.kernels import local_stats as ls_op

    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (4, 128))
    wu = jax.random.normal(ks[1], (128, 512)) * 0.05
    wg = jax.random.normal(ks[2], (128, 512)) * 0.05
    wd = jax.random.normal(ks[3], (512, 128)) * 0.05
    bidx = jnp.asarray([0, 3], jnp.int32)
    out = gf_op(x, wu, wd, bidx, wg)
    ref = glass_ffn_ref(x, wu, wd, bidx, wg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    q = jax.random.normal(ks[4], (1, 2, 64, 32))
    o = fa_op(q, q, q, block_q=32, block_k=32)
    r = flash_attention_ref(q, q, q)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5, rtol=2e-5)

    h = jax.random.normal(ks[0], (128, 256))
    np.testing.assert_allclose(
        np.asarray(ls_op(h, block_t=64, block_m=128)),
        np.asarray(local_stats_ref(h)), atol=1e-4, rtol=1e-4,
    )
