"""Checkpointing (atomic/rotation/async/elastic) + fault-tolerance logic."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft.watchdog import Heartbeat, StepWatchdog, check_peers, plan_elastic_mesh
from tests.helpers import run_with_devices


def _tree():
    return {"layer": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
            "step_scale": jnp.float32(2.5)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t, extra={"pipeline": {"next_doc": 3}})
    step, out, extra = restore_checkpoint(tmp_path, t)
    assert step == 7 and extra["pipeline"]["next_doc"] == 3
    np.testing.assert_array_equal(np.asarray(out["layer"]["w"]), np.asarray(t["layer"]["w"]))


def test_rotation_keeps_latest(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, t, keep=3)
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 3 and kept[-1] == "step_00000005"


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    ck.save(1, _tree())
    ck.save(2, _tree())  # waits for 1 internally
    ck.wait()
    assert latest_step(tmp_path) == 2


def test_train_resume_after_simulated_failure(tmp_path):
    """Kill training mid-run; resume must continue the exact data stream."""
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.models import ModelConfig, build_model
    from repro.train.loop import TrainConfig, train

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=300,
                      dtype="float32", remat="none")
    model = build_model(cfg)
    corpus = SyntheticCorpus(CorpusConfig(seed=3))
    tc_full = TrainConfig(steps=8, batch=2, seq=32, ckpt_dir=None, log_every=100)
    full = train(model, tc_full, corpus, log=lambda s: None)

    d = str(tmp_path / "ck")
    tc_a = TrainConfig(steps=4, batch=2, seq=32, ckpt_dir=d, ckpt_every=4, log_every=100)
    train(model, tc_a, corpus, log=lambda s: None)  # "crash" after step 4
    tc_b = TrainConfig(steps=8, batch=2, seq=32, ckpt_dir=d, ckpt_every=4, log_every=100)
    resumed = train(model, tc_b, corpus, log=lambda s: None)
    assert resumed["resumed_from"] == 4
    np.testing.assert_allclose(resumed["losses"], full["losses"][4:], rtol=2e-4, atol=2e-5)


def test_elastic_restore_different_mesh(tmp_path):
    """Checkpoint saved unsharded loads onto an 8-device mesh (and back)."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"w": jnp.arange(64.0).reshape(8, 8)})
    out = run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(4, 2)
tpl = {{"w": jnp.zeros((8, 8))}}
sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
step, tree, _ = restore_checkpoint(r'{d}', tpl, shardings=sh)
assert tree["w"].sharding.is_equivalent_to(sh["w"], 2)
np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(64.).reshape(8, 8))
save_checkpoint(r'{d}2', 2, tree)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
    # and back onto a single device
    step, tree, _ = restore_checkpoint(str(tmp_path / "ck2"), {"w": jnp.zeros((8, 8))})
    assert step == 2


def test_watchdog_flags_straggler():
    wd = StepWatchdog(warmup_steps=3, k_sigma=3.0)
    for i in range(20):
        assert not wd.observe(i, 0.10 + 0.001 * (i % 3))
    assert wd.observe(20, 0.50)
    assert wd.slow_steps and wd.slow_steps[-1][0] == 20


def test_heartbeats_and_remesh(tmp_path):
    for h in range(4):
        Heartbeat(tmp_path, h).beat(step=10)
    # age host 3's heartbeat artificially
    p = tmp_path / "heartbeat_00003.json"
    d = json.loads(p.read_text()); d["t"] -= 1000; p.write_text(json.dumps(d))
    status = check_peers(tmp_path, timeout_s=60)
    assert status["alive"] == [0, 1, 2] and status["dead"] == [3]
    plan = plan_elastic_mesh(n_healthy_hosts=3, chips_per_host=8, model_parallel=16)
    assert plan == (1, 16)
    assert plan_elastic_mesh(1, 8, 16) is None
