"""GLASS local-importance accumulator (Pallas, TPU target).

Computes sum_t |h_t| / ||h_t||_2 over a (T, m) hidden-activation stream in
two tiled passes:

  pass 1 — row norms: grid (nT, nM), accumulate sum of squares per row tile
           into a (T, 1) scratch-backed output (m is the inner, sequential
           axis so the accumulator is revisited safely);
  pass 2 — normalized-abs accumulation: grid (nM, nT) with T inner, adding
           |h| / norm row-blocks into the (1, m) output.

This is the kernel the prefill pass fuses its A^l statistics through: each
tile is touched exactly once per pass, so the extra HBM traffic over the
plain FFN forward is ~2x reads of h (vs 3x for the unfused jnp version which
materializes |h| and h^2 separately).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _norms_kernel(h_ref, o_ref, *, nm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(h * h, axis=1, keepdims=True)


def _accum_kernel(h_ref, n_ref, o_ref, *, nt: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...].astype(jnp.float32)
    nrm = jnp.sqrt(n_ref[...]) + EPS  # (bt, 1)
    o_ref[...] += jnp.sum(jnp.abs(h) / nrm, axis=0, keepdims=True)


def local_stats(
    h: jax.Array,  # (T, m)
    *,
    block_t: int = 256,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns (m,) f32: sum over rows of |h|/||h||_2."""
    T, m = h.shape
    bt, bm = min(block_t, T), min(block_m, m)
    assert T % bt == 0 and m % bm == 0, (T, bt, m, bm)
    nt, nm = T // bt, m // bm

    sumsq = pl.pallas_call(
        functools.partial(_norms_kernel, nm=nm),
        grid=(nt, nm),
        in_specs=[pl.BlockSpec((bt, bm), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 1), jnp.float32),
        interpret=interpret,
    )(h)

    out = pl.pallas_call(
        functools.partial(_accum_kernel, nt=nt),
        grid=(nm, nt),
        in_specs=[
            pl.BlockSpec((bt, bm), lambda j, i: (i, j)),
            pl.BlockSpec((bt, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        interpret=interpret,
    )(h, sumsq)
    return out[0]
