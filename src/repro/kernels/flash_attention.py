"""Flash attention (Pallas, TPU target): causal / sliding-window / softcap.

Grid (batch*heads, nq, nk) — TPU grids run sequentially minor-to-major, so
the kv axis is innermost and the online-softmax state (m, l, acc) lives in
VMEM scratch carried across kv steps:

    m_new = max(m, rowmax(s));  alpha = exp(m - m_new)
    l     = l * alpha + rowsum(exp(s - m_new))
    acc   = acc * alpha + exp(s - m_new) @ v

Blocks fully outside the causal/window band are skipped with pl.when (they
cost a grid step but no MXU work).  The final kv step normalizes by l.

VMEM per step: q/k/v tiles (3 * bq|bk x hd) + acc (bq, hd) f32 + scores
(bq, bk) f32 — a few MB for the default 512x512 tiling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0e38


def _kernel(
    q_ref, k_ref, v_ref, o_ref,  # (1, bq, hd), (1, bk, hd), (1, bk, hd), (1, bq, hd)
    m_ref, l_ref, acc_ref,  # scratch: (bq, 1), (bq, 1), (bq, hd)
    *,
    bq: int,
    bk: int,
    nk: int,
    seq_q: int,
    seq_kv: int,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: causal => kv block start must not exceed q block end
    q_end = (iq + 1) * bq - 1 + (seq_kv - seq_q)  # align ends
    k_start = ik * bk
    in_band = True
    if causal:
        in_band = k_start <= q_end
    if window is not None:
        # kv block end must be within window of the q block start
        q_start = iq * bq + (seq_kv - seq_q)
        in_band = jnp.logical_and(in_band, (q_start - ((ik + 1) * bk - 1)) < window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (seq_kv - seq_q)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, H, Skv, hd)
    v: jax.Array,  # (B, H, Skv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    scale = scale if scale is not None else hd**-0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Skv, hd)
    vf = v.reshape(B * H, Skv, hd)

    kernel = functools.partial(
        _kernel,
        bq=bq, bk=bk, nk=nk, seq_q=Sq, seq_kv=Skv,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
