from .ops import flash_attention, glass_ffn, local_stats, paged_attention

__all__ = ["flash_attention", "glass_ffn", "local_stats", "paged_attention"]
