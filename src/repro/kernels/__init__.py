from .ops import flash_attention, glass_ffn, local_stats

__all__ = ["flash_attention", "glass_ffn", "local_stats"]
