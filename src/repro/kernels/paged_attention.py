"""Fused paged-attention decode kernel (Pallas, TPU target).

Replaces the XLA gather in :func:`repro.models.attention.attention_decode_paged`
that materializes every row's full ``(nb * bs)`` logical KV view per tick.
Grid ``(slot, query, kv_head, kv_block)`` — TPU grids run sequentially
minor-to-major, so the kv-block axis is innermost and the online-softmax
state (m, l, acc) lives in VMEM scratch carried across kv blocks, exactly
like flash_attention.py:

    m_new = max(m, rowmax(s));  alpha = exp(m - m_new)
    l     = l * alpha + rowsum(p);   p = where(mask, exp(s - m_new), 0)
    acc   = acc * alpha + p @ v

Each program streams only ONE physical KV block HBM->VMEM: the block id is
read from the scalar-prefetched block table inside the BlockSpec index map,
so the ``(B, nb*bs, K, hd)`` gathered view is never materialized and the
HBM traffic per row scales with ``ceil((cache_len + T) / bs)`` live blocks,
not the ``nb`` allocated capacity.  Blocks past a row's frontier or fully
below its sliding window are skipped (``pl.when``) — their table entries
point at trash block 0, so even the prefetch pipeline re-reads one hot
block instead of walking the pool.

``T >= 1`` queries per row share one kernel: T = 1 is the decode tick,
T > 1 serves chunked prefill and the parallel multi-token speculative
verify.  The query axis deliberately lives on the GRID, not inside the
block shapes: every (slot, query, head) program runs the exact same traced
op graph at the exact same ``(G, bs)`` shapes whatever T is, which is what
makes a T = k+1 verify forward produce bitwise the tokens and KV rows of
k+1 sequential T = 1 ticks (a T-wide q tile compiles to differently fused
reductions and costs 1-ulp divergences).  Masked entries contribute EXACT
zeros to l/acc (the ``where`` below, not ``exp(NEG - m)``), which makes
the accumulator bitwise independent of how many dead or out-of-window
blocks a bucket carries — the engine's pow2 bucketing and the
sequential-vs-parallel verify bit-equality contract both rely on it.

The sliding ``window`` is a *traced* int32 scalar (scalar-prefetch operand)
so a single compiled kernel serves local and global layers inside the layer
scan — global layers pass ``2**30`` exactly like ``layer_windows``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0e38


def _kernel(
    tab_ref,  # scalar prefetch: (B, nb) int32 block table
    clen_ref,  # scalar prefetch: (B,) int32 live rows before the T new tokens
    wnd_ref,  # scalar prefetch: (1,) int32 sliding window (2**30 = global)
    q_ref,  # (1, 1, 1, G, hd) — query t of slot b, head h
    k_ref,  # (1, bs, 1, hd) — physical block tab[b, j], head h
    v_ref,  # (1, bs, 1, hd)
    o_ref,  # (1, 1, 1, G, hd)
    m_ref,  # scratch (G, 1) f32
    l_ref,  # scratch (G, 1) f32
    acc_ref,  # scratch (G, hd) f32
    *,
    bs: int,
    T: int,
    nb: int,
    softcap: Optional[float],
    scale: float,
):
    b = pl.program_id(0)
    t = pl.program_id(1)
    j = pl.program_id(3)
    clen = clen_ref[b]
    wnd = wnd_ref[0]
    G, hd = acc_ref.shape
    qpos = clen + t  # this query's logical position

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level skip: past this query's causal frontier (kpos > qpos for
    # the whole block) or entirely below its window.  Skipped blocks are
    # exactly those whose every entry would mask to a zero contribution,
    # so skipping is bitwise-identical to processing them.
    live = (j * bs) <= qpos
    in_window = (qpos - ((j + 1) * bs - 1)) < wnd

    @pl.when(live & in_window)
    def _compute():
        q = q_ref[0, 0, 0]  # (G, hd)
        k = k_ref[0, :, 0]  # (bs, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
        mask = (qpos >= kpos) & ((qpos - kpos) < wnd)
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # Masked entries contribute an EXACT 0 (not exp(NEG - m), which is 0
        # only once a live m is around): a block whose every entry is below
        # this query's window must leave l/acc untouched even while m is
        # still NEG.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, :, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,  # (B, T, K, G, hd) post-RoPE grouped queries
    cache_k: jax.Array,  # (num_blocks, bs, K, hd) shared block pool
    cache_v: jax.Array,
    block_table: jax.Array,  # (B, nb) int32 block ids in logical order
    cache_len: jax.Array,  # (B,) int32 rows already live (before the T new)
    window: jax.Array,  # () or (1,) int32 traced sliding window
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Attend T queries per row against that row's paged KV blocks.

    The new tokens' k/v rows must already be scattered into the pool (the
    caller owns the write — an in-kernel scatter could touch physical
    blocks no table references, i.e. free or prefix-cache-retained blocks
    whose content must survive).  The causal intra-chunk mask places query
    ``t`` at logical position ``cache_len + t``, so the scattered frontier
    rows participate exactly like :func:`attention_decode_paged`'s gather.
    Returns (B, T, K, G, hd) in q.dtype.
    """
    B, T, K, G, hd = q.shape
    nb = block_table.shape[1]
    bs = cache_k.shape[1]
    scale = scale if scale is not None else hd**-0.5
    tab = block_table.astype(jnp.int32)
    clen = cache_len.astype(jnp.int32)
    wnd = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _kernel, bs=bs, T=T, nb=nb, softcap=softcap, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, T, K, nb),
        in_specs=[
            pl.BlockSpec(
                (1, 1, 1, G, hd), lambda b, t, h, j, tab, cl, w: (b, t, h, 0, 0)
            ),
            pl.BlockSpec(
                (1, bs, 1, hd), lambda b, t, h, j, tab, cl, w: (tab[b, j], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, bs, 1, hd), lambda b, t, h, j, tab, cl, w: (tab[b, j], 0, h, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, G, hd), lambda b, t, h, j, tab, cl, w: (b, t, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, K, G, hd), q.dtype),
        interpret=interpret,
    )(tab, clen, wnd, q, cache_k, cache_v)
