"""Block-sparse GLASS FFN decode kernel (Pallas, TPU target).

The TPU-native execution of a GLASS mask: FFN hidden units are grouped into
blocks of ``block_size`` (>= 128 = lane width); the mask keeps whole blocks
(see core.fusion.select_blocks).  The kernel receives the *active block index
list* via scalar prefetch and streams only the active (d x bs) weight tiles
HBM->VMEM — inactive blocks are never read, which is exactly the paper's
"compact FFN residency" I/O story, expressed with MXU-shaped tiles.

    y = (act(x @ Wg[:, blk]) * (x @ Wu[:, blk])) @ Wd[blk, :]   summed over
    active blocks blk.

Grid: one step per active block; the f32 accumulator lives in the output ref
(TPU grids execute sequentially, so revisiting the output block is safe).

``block_scale`` is the per-request density hook: each listed block's
contribution is multiplied by a per-(row, tile) f32 before accumulation.
The engine selects blocks at its CAPACITY density and scales a lower-density
request's dropped tiles by exactly 0.0 — a zero contribution added to the
accumulator is bitwise a no-op, so a scaled row equals running the shorter
list outright, while every row still shares one fixed-width compiled grid.
(The tiles are still streamed; per-request density trades I/O for not
recompiling per request.  ``None`` keeps the original unscaled program.)

VMEM budget per step (worst assigned case d = 8192, bs = 128, B <= 128):
x 2 MiB + 3 weight tiles 6 MiB + acc 4 MiB ~= 12 MiB < 16 MiB.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda t: jnp.square(jax.nn.relu(t)),
}


def _tile_contrib(x, wg_ref, wu_ref, wd_ref, *, act: str, gated: bool):
    up = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    if gated:
        gate = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
        h = _ACTS[act](gate) * up
    else:
        h = _ACTS[act](up)
    return jnp.dot(
        h.astype(wd_ref.dtype), wd_ref[...], preferred_element_type=jnp.float32
    )


def _kernel(idx_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, act: str, gated: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _tile_contrib(x_ref[...], wg_ref, wu_ref, wd_ref, act=act, gated=gated)


def _kernel_scaled(
    idx_ref, x_ref, sc_ref, wg_ref, wu_ref, wd_ref, o_ref, *, act: str, gated: bool
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += sc_ref[0, i] * _tile_contrib(
        x_ref[...], wg_ref, wu_ref, wd_ref, act=act, gated=gated
    )


def glass_ffn_block_sparse(
    x: jax.Array,  # (B, d)
    w_up: jax.Array,  # (d, m)
    w_down: jax.Array,  # (m, d)
    block_idx: jax.Array,  # (nb_active,) int32 — active block ids
    w_gate: jax.Array | None = None,  # (d, m)
    *,
    block_scale: jax.Array | None = None,  # (nb_active,) f32 tile multipliers
    act: str = "silu",
    block_size: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, d) f32. Only active weight blocks are read from HBM."""
    B, d = x.shape
    m = w_up.shape[1]
    assert m % block_size == 0, (m, block_size)
    nb = block_idx.shape[0]
    gated = w_gate is not None
    if not gated:  # dummy ref so the kernel signature stays uniform
        w_gate = w_up

    weight_specs = [
        pl.BlockSpec((d, block_size), lambda i, idx: (0, idx[i])),  # w_gate tile
        pl.BlockSpec((d, block_size), lambda i, idx: (0, idx[i])),  # w_up tile
        pl.BlockSpec((block_size, d), lambda i, idx: (idx[i], 0)),  # w_down tile
    ]
    x_spec = pl.BlockSpec((B, d), lambda i, idx: (0, 0))  # x: resident
    out_spec = pl.BlockSpec((B, d), lambda i, idx: (0, 0))
    if block_scale is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(nb,),
            in_specs=[x_spec] + weight_specs, out_specs=out_spec,
        )
        fn = pl.pallas_call(
            functools.partial(_kernel, act=act, gated=gated),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
            interpret=interpret,
        )
        return fn(block_idx, x, w_gate, w_up, w_down)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(nb,),
        in_specs=[x_spec, pl.BlockSpec((1, nb), lambda i, idx: (0, 0))] + weight_specs,
        out_specs=out_spec,
    )
    fn = pl.pallas_call(
        functools.partial(_kernel_scaled, act=act, gated=gated),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )
    sc = jnp.asarray(block_scale, jnp.float32).reshape(1, nb)
    return fn(block_idx, x, sc, w_gate, w_up, w_down)


def _kernel_rowwise(idx_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, act: str, gated: bool):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _tile_contrib(x_ref[...], wg_ref, wu_ref, wd_ref, act=act, gated=gated)


def _kernel_rowwise_scaled(
    idx_ref, x_ref, sc_ref, wg_ref, wu_ref, wd_ref, o_ref, *, act: str, gated: bool
):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += sc_ref[0, i] * _tile_contrib(
        x_ref[...], wg_ref, wu_ref, wd_ref, act=act, gated=gated
    )


def glass_ffn_block_sparse_rowwise(
    x: jax.Array,  # (B, d) — B serving slots, each with its OWN block list
    w_up: jax.Array,  # (d, m)
    w_down: jax.Array,  # (m, d)
    block_idx: jax.Array,  # (B, nb_active) int32 — per-row active block ids
    w_gate: jax.Array | None = None,  # (d, m)
    *,
    block_scale: jax.Array | None = None,  # (B, nb_active) f32 tile multipliers
    act: str = "silu",
    block_size: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-row block-sparse GLASS FFN: the continuous-batching decode path.

    Each serving slot carries its own prompt-adaptive mask, so the active
    block list differs per row.  Grid (B, nb): step (b, i) streams row b's
    i-th active weight tiles; the row's f32 accumulator lives in its (1, d)
    output block (consecutive grid steps revisit it, which is safe on TPU's
    sequential grid).  Rows are processed independently — batching rows that
    share a block list into the shared-list kernel is a further optimization
    the engine can apply when masks collide.  ``block_scale`` multiplies row
    b's i-th tile contribution (per-request GLASS density nested inside the
    capacity-tier list; 0.0 exactly drops a tile).  Returns (B, d) f32.
    """
    B, d = x.shape
    m = w_up.shape[1]
    assert m % block_size == 0, (m, block_size)
    assert block_idx.shape[0] == B, (block_idx.shape, B)
    nb = block_idx.shape[1]
    gated = w_gate is not None
    if not gated:  # dummy ref so the kernel signature stays uniform
        w_gate = w_up

    weight_specs = [
        pl.BlockSpec((d, block_size), lambda b, i, idx: (0, idx[b, i])),  # w_gate tile
        pl.BlockSpec((d, block_size), lambda b, i, idx: (0, idx[b, i])),  # w_up tile
        pl.BlockSpec((block_size, d), lambda b, i, idx: (idx[b, i], 0)),  # w_down tile
    ]
    x_spec = pl.BlockSpec((1, d), lambda b, i, idx: (b, 0))  # x: row b resident
    out_spec = pl.BlockSpec((1, d), lambda b, i, idx: (b, 0))
    if block_scale is None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(B, nb),
            in_specs=[x_spec] + weight_specs, out_specs=out_spec,
        )
        fn = pl.pallas_call(
            functools.partial(_kernel_rowwise, act=act, gated=gated),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
            interpret=interpret,
        )
        return fn(block_idx, x, w_gate, w_up, w_down)
    assert block_scale.shape == block_idx.shape, (block_scale.shape, block_idx.shape)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(B, nb),
        in_specs=[x_spec, pl.BlockSpec((1, nb), lambda b, i, idx: (b, 0))] + weight_specs,
        out_specs=out_spec,
    )
    fn = pl.pallas_call(
        functools.partial(_kernel_rowwise_scaled, act=act, gated=gated),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )
    sc = jnp.asarray(block_scale, jnp.float32)
    return fn(block_idx, x, sc, w_gate, w_up, w_down)
