"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda t: jnp.square(jax.nn.relu(t)),
}


def glass_ffn_ref(
    x: jax.Array,  # (B, d)
    w_up: jax.Array,  # (d, m)
    w_down: jax.Array,  # (m, d)
    block_idx: jax.Array,  # (nb_active,)
    w_gate: jax.Array | None = None,
    *,
    act: str = "silu",
    block_size: int = 128,
) -> jax.Array:
    """Masked full-width FFN == block-sparse kernel output (f32)."""
    m = w_up.shape[1]
    nb = m // block_size
    bmask = jnp.zeros((nb,), jnp.float32).at[block_idx].set(1.0)
    mask = jnp.repeat(bmask, block_size)
    x32 = x.astype(jnp.float32)
    up = x32 @ w_up.astype(jnp.float32)
    if w_gate is not None:
        h = _ACTS[act](x32 @ w_gate.astype(jnp.float32)) * up
    else:
        h = _ACTS[act](up)
    h = h * mask
    return h @ w_down.astype(jnp.float32)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, H, Skv, hd)
    v: jax.Array,  # (B, H, Skv, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    hd = q.shape[-1]
    scale = scale if scale is not None else hd**-0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # align ends (prefill: Sq==Skv)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def local_stats_ref(h: jax.Array, eps: float = 1e-6) -> jax.Array:
    """sum over rows of |h_t| / ||h_t||_2 — (T, m) -> (m,) f32."""
    h32 = h.astype(jnp.float32)
    nrm = jnp.sqrt(jnp.sum(jnp.square(h32), axis=-1, keepdims=True))
    return jnp.sum(jnp.abs(h32) / (nrm + eps), axis=0)
