"""Jit'd public entry points for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (kernels execute via the Pallas
interpreter for correctness work) and should be False on real TPU backends —
callers flip it via the module-level ``INTERPRET`` or per-call.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .glass_ffn import glass_ffn_block_sparse as _glass_ffn
from .glass_ffn import glass_ffn_block_sparse_rowwise as _glass_ffn_rowwise
from .local_stats import local_stats as _local_stats
from .paged_attention import paged_attention as _paged_attention

INTERPRET = jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("act", "block_size", "interpret"))
def glass_ffn(
    x, w_up, w_down, block_idx, w_gate=None, *, block_scale=None, act="silu",
    block_size=128, interpret=None,
):
    """Block-sparse GLASS FFN decode step: only active weight blocks are read."""
    it = INTERPRET if interpret is None else interpret
    return _glass_ffn(
        x, w_up, w_down, block_idx, w_gate, block_scale=block_scale,
        act=act, block_size=block_size, interpret=it,
    )


@partial(jax.jit, static_argnames=("act", "block_size", "interpret"))
def glass_ffn_rowwise(
    x, w_up, w_down, block_idx, w_gate=None, *, block_scale=None, act="silu",
    block_size=128, interpret=None,
):
    """Per-row block-sparse GLASS FFN: block_idx (B, nb) — one prompt-adaptive
    block list per serving slot (the continuous-batching decode path).
    ``block_scale`` (B, nb) multiplies each row's tile contributions (the
    per-request density hook)."""
    it = INTERPRET if interpret is None else interpret
    return _glass_ffn_rowwise(
        x, w_up, w_down, block_idx, w_gate, block_scale=block_scale,
        act=act, block_size=block_size, interpret=it,
    )


@partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None,
    block_q=512, block_k=512, interpret=None,
):
    it = INTERPRET if interpret is None else interpret
    return _flash(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=it,
    )


@partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_attention(
    q, cache_k, cache_v, block_table, cache_len, window, *,
    softcap=None, scale=None, interpret=None,
):
    """Fused paged-attention decode: block-table gather + online-softmax
    attention in one pass — the caller scatters the new k/v rows first.
    ``window`` is a traced int32 scalar (pass 2**30 for global layers)."""
    it = INTERPRET if interpret is None else interpret
    return _paged_attention(
        q, cache_k, cache_v, block_table, cache_len, window,
        softcap=softcap, scale=scale, interpret=it,
    )


@partial(jax.jit, static_argnames=("block_t", "block_m", "interpret"))
def local_stats(h, *, block_t=256, block_m=512, interpret=None):
    it = INTERPRET if interpret is None else interpret
    return _local_stats(h, block_t=block_t, block_m=block_m, interpret=it)
