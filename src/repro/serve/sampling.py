"""Sampling primitives shared by the serving engine and NPS.

Implements the paper's NPS sampling settings (App. B.3): top-k filtering,
temperature, and a bigram repetition penalty used for the first "hot" steps.
The bigram tracker is a dense (B, V, V) boolean table — exact and fast for
the vocabularies used in-repo; swap for a hashed ring buffer at 100k+ vocab
(the table is only used offline during prior computation, never at serve
time).

Serving additions (per-request generation API):

``SamplingParams`` is the request-scoped sampling policy the paged engine
threads into its jitted decode scans, and ``sample_positional`` is the
**counter-based PRNG** draw behind it: every sampled token is a pure
function of ``(request seed, generated position, logits)`` — no engine-
global RNG stream is ever consumed.  That makes sampled streams
reproducible *by construction*: swap/recompute resume, forced-token
replay, and speculative draft/verify all regenerate bit-identical tokens
because position ``p`` always folds the same key.  Greedy decoding is the
``seed=None`` special case.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30

# per-request stop-set capacity in the jitted decode scan: eos_token_id plus
# up to MAX_STOP_IDS - 1 extra stop ids ride in one fixed (B, MAX_STOP_IDS)
# int32 input (padded with -1) so early-finish detection adds no jit variants
MAX_STOP_IDS = 4


@dataclass(frozen=True)
class SamplingParams:
    """Request-scoped sampling policy (the vLLM-style per-request knob).

    ``seed=None`` (or ``greedy=True``, or ``temperature <= 0``) selects
    greedy argmax decoding.  A seeded request samples with a counter-based
    PRNG keyed on ``(seed, generated position)`` — see
    :func:`sample_positional` — so its stream survives preemption, replay,
    and speculative rollback bit-identically.

    ``eos_token_id`` / ``stop_token_ids`` finish the request early
    (``finish_reason`` "eos" / "stop"); the matched token is included in
    the output.  At most :data:`MAX_STOP_IDS` ids total (eos counts).
    """

    temperature: float = 1.0
    top_k: int = 0  # 0 = no top-k filtering
    top_p: float = 1.0  # 1.0 = no nucleus filtering
    min_p: float = 0.0  # 0.0 = no min-p filtering
    seed: Optional[int] = None  # None = greedy (the special case)
    greedy: bool = False  # force greedy even with a seed set
    eos_token_id: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if not (0.0 <= self.min_p <= 1.0):
            raise ValueError(f"min_p must be in [0, 1], got {self.min_p}")
        object.__setattr__(self, "stop_token_ids", tuple(self.stop_token_ids))
        if len(self.stop_set) > MAX_STOP_IDS:
            raise ValueError(
                f"at most {MAX_STOP_IDS} stop ids (eos included), got {self.stop_set}"
            )

    @property
    def is_greedy(self) -> bool:
        return self.greedy or self.seed is None or self.temperature <= 0.0

    @property
    def stop_set(self) -> Tuple[int, ...]:
        """All token ids that finish the request early (eos first)."""
        eos = () if self.eos_token_id is None else (self.eos_token_id,)
        return eos + tuple(t for t in self.stop_token_ids if t != self.eos_token_id)

    @classmethod
    def make_greedy(cls, *, eos_token_id: Optional[int] = None,
                    stop_token_ids: Tuple[int, ...] = ()) -> "SamplingParams":
        return cls(temperature=0.0, greedy=True, eos_token_id=eos_token_id,
                   stop_token_ids=stop_token_ids)


def positional_key(seed: jax.Array, pos: jax.Array) -> jax.Array:
    """The counter-based PRNG key for one (request, position) draw.

    ``fold_in(fold_in(key(0), seed), pos)`` — a pure function of the two
    integers, so replay at the same position regenerates the same key no
    matter what the engine did in between (the reproducibility contract
    every resume path relies on)."""
    base = jax.random.key(0)
    return jax.random.fold_in(jax.random.fold_in(base, seed), pos)


def top_k_filter_dynamic(logits: jax.Array, k: jax.Array) -> jax.Array:
    """Per-row top-k filter with a *traced* k (B,): keep each row's k
    largest logits (k = 0 or >= V keeps everything).  The static-k
    :func:`top_k_filter` stays for the offline NPS path."""
    V = logits.shape[-1]
    srt = jnp.sort(logits, axis=-1)[..., ::-1]  # descending per row
    kk = jnp.clip(k, 0, V)
    th = jnp.take_along_axis(srt, jnp.maximum(kk - 1, 0)[..., None], axis=-1)
    keep = (kk[..., None] <= 0) | (logits >= th)
    return jnp.where(keep, logits, NEG)


def top_p_filter_dynamic(logits: jax.Array, p: jax.Array) -> jax.Array:
    """Per-row nucleus (top-p) filter with a *traced* p (B,): keep the
    smallest set of tokens whose probability mass reaches ``p[b]``
    (p >= 1 keeps everything; the argmax always survives).  Same
    sort-then-threshold shape as :func:`top_k_filter_dynamic`, so it adds
    no data-dependent control flow to the fused decode scan."""
    probs = jax.nn.softmax(logits, axis=-1)
    srt = jnp.sort(probs, axis=-1)[..., ::-1]  # descending per row
    cum = jnp.cumsum(srt, axis=-1)
    # a sorted entry is kept while the mass BEFORE it is < p; map that back
    # to vocab order via the per-row probability threshold of the last kept
    # sorted entry (ties keep both — a superset never drops the nucleus)
    keep_sorted = (cum - srt) < p[..., None]
    # threshold = the SMALLEST kept sorted prob (the first entry is always
    # kept, so the min is well-defined)
    th = jnp.min(jnp.where(keep_sorted, srt, jnp.inf), axis=-1, keepdims=True)
    keep = (p[..., None] >= 1.0) | (probs >= th)
    return jnp.where(keep, logits, NEG)


def min_p_filter_dynamic(logits: jax.Array, mp: jax.Array) -> jax.Array:
    """Per-row min-p filter with a *traced* mp (B,): keep tokens whose
    probability is >= ``mp[b]`` times the row's max probability (mp = 0
    keeps everything; the argmax always survives by construction)."""
    probs = jax.nn.softmax(logits, axis=-1)
    top = jnp.max(probs, axis=-1, keepdims=True)
    keep = probs >= mp[..., None] * top
    return jnp.where(keep, logits, NEG)


def sample_positional(
    logits: jax.Array,  # (B, V) f32
    seeds: jax.Array,  # (B,) int32/uint32 per-request seeds
    pos: jax.Array,  # (B,) int32 generated position of THIS draw
    temperature: jax.Array,  # (B,) f32
    top_k: jax.Array,  # (B,) int32 (0 = off)
    top_p: Optional[jax.Array] = None,  # (B,) f32 (1.0 = off)
    min_p: Optional[jax.Array] = None,  # (B,) f32 (0.0 = off)
) -> jax.Array:
    """Counter-based per-slot sampling: row ``b`` draws from
    ``logits[b]`` with key ``positional_key(seeds[b], pos[b])`` after
    per-row temperature scaling and dynamic top-k / top-p / min-p
    filtering (filters compose in that order, each per-row traced).

    Deterministic per (seed, position, logits) — the engine's sampled
    streams are replayable because this function has no other inputs.
    Returns (B,) int32 token ids."""
    logits = logits.astype(jnp.float32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    filt = top_k_filter_dynamic(scaled, top_k)
    if top_p is not None:
        filt = top_p_filter_dynamic(filt, top_p)
    if min_p is not None:
        filt = min_p_filter_dynamic(filt, min_p)
    keys = jax.vmap(positional_key)(seeds, pos)
    return jax.vmap(jax.random.categorical)(keys, filt).astype(jnp.int32)


def top_k_filter(logits: jax.Array, k: int) -> jax.Array:
    """Keep the k largest logits per row, others -> -inf."""
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    vals, _ = jax.lax.top_k(logits, k)
    thresh = vals[..., -1:]
    return jnp.where(logits >= thresh, logits, NEG)


def sample(
    rng: jax.Array,
    logits: jax.Array,  # (B, V) f32
    *,
    temperature: jax.Array | float = 1.0,
    top_k: int = 0,
) -> jax.Array:
    logits = logits.astype(jnp.float32) / jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
    logits = top_k_filter(logits, top_k)
    return jax.random.categorical(rng, logits, axis=-1)


def bigram_init(batch: int, vocab: int) -> jax.Array:
    return jnp.zeros((batch, vocab, vocab), bool)


def bigram_update(seen: jax.Array, prev_tok: jax.Array, new_tok: jax.Array) -> jax.Array:
    """Mark (prev, new) bigram per batch row. prev/new (B,) int32."""
    b = jnp.arange(seen.shape[0])
    return seen.at[b, prev_tok, new_tok].set(True)


def bigram_penalize(
    logits: jax.Array,  # (B, V)
    seen: jax.Array,  # (B, V, V)
    prev_tok: jax.Array,  # (B,)
    penalty: float,
    enabled: jax.Array | bool = True,
) -> jax.Array:
    b = jnp.arange(logits.shape[0])
    seen_row = seen[b, prev_tok].astype(jnp.float32)  # (B, V)
    pen = penalty * seen_row * jnp.asarray(enabled, jnp.float32)
    return logits - pen
