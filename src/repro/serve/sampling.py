"""Sampling primitives shared by the serving engine and NPS.

Implements the paper's NPS sampling settings (App. B.3): top-k filtering,
temperature, and a bigram repetition penalty used for the first "hot" steps.
The bigram tracker is a dense (B, V, V) boolean table — exact and fast for
the vocabularies used in-repo; swap for a hashed ring buffer at 100k+ vocab
(the table is only used offline during prior computation, never at serve
time).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def top_k_filter(logits: jax.Array, k: int) -> jax.Array:
    """Keep the k largest logits per row, others -> -inf."""
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    vals, _ = jax.lax.top_k(logits, k)
    thresh = vals[..., -1:]
    return jnp.where(logits >= thresh, logits, NEG)


def sample(
    rng: jax.Array,
    logits: jax.Array,  # (B, V) f32
    *,
    temperature: jax.Array | float = 1.0,
    top_k: int = 0,
) -> jax.Array:
    logits = logits.astype(jnp.float32) / jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
    logits = top_k_filter(logits, top_k)
    return jax.random.categorical(rng, logits, axis=-1)


def bigram_init(batch: int, vocab: int) -> jax.Array:
    return jnp.zeros((batch, vocab, vocab), bool)


def bigram_update(seen: jax.Array, prev_tok: jax.Array, new_tok: jax.Array) -> jax.Array:
    """Mark (prev, new) bigram per batch row. prev/new (B,) int32."""
    b = jnp.arange(seen.shape[0])
    return seen.at[b, prev_tok, new_tok].set(True)


def bigram_penalize(
    logits: jax.Array,  # (B, V)
    seen: jax.Array,  # (B, V, V)
    prev_tok: jax.Array,  # (B,)
    penalty: float,
    enabled: jax.Array | bool = True,
) -> jax.Array:
    b = jnp.arange(logits.shape[0])
    seen_row = seen[b, prev_tok].astype(jnp.float32)  # (B, V)
    pen = penalty * seen_row * jnp.asarray(enabled, jnp.float32)
    return logits - pen
