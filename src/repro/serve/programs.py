"""Centralized compiled-program registry for the serving engines.

Every jitted program an engine builds (decode scan, prefill chunk, parallel
verify, ...) registers here, so the compiled-variant population is observable
in ONE place.  The engines deliberately bound recompilation by bucketing the
dynamic axes that would otherwise explode the jit cache:

  * gather width   — ``pow2_bucket`` over the block-table width ``nb``
  * scan horizon   — power-of-two ``H`` via the fused-decode horizon
  * glass mode     — a static of the program closure (one program per mode)
  * group shape    — canonicalized shared-list group sizes (partitions, not
                     compositions, of ``max_slots``)

jax.jit keys its own cache on exactly those (shapes + statics), so the
variant count per program is the product of the buckets actually served —
NOT of the raw lengths.  ``ProgramCache.sizes()`` exposes the per-program
compiled counts (via the jitted callable's ``_cache_size``), which is what
the recompile-churn regression test asserts on: replaying an identical
workload must not add a single variant.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import jax

# the canonical bucket helper lives with the pool (widths are a pool
# property); re-exported here so program-cache users need one import
from .kv_pool import pow2_bucket  # noqa: F401


class ProgramCache:
    """Named registry of an engine's jitted entry points.

    ``register`` wraps a function with ``jax.jit`` and remembers the jitted
    callable; ``sizes``/``total`` report how many program variants each has
    compiled so far.  ``snapshot`` + ``misses_since`` give the churn between
    two points of a run — zero across a replay of an identical workload is
    the invariant the engines maintain.

    ``namespace`` scopes the reported names (``"replica1/decode"``): every
    engine owns its OWN registry (so cluster replicas can never collide on
    a ``register`` name, and each replica's programs follow its params onto
    its own device slice), and the namespace is what keeps the per-replica
    populations tellable apart when a cluster aggregates them for the
    churn accounting.
    """

    def __init__(self, namespace: str = "") -> None:
        self._fns: Dict[str, Callable] = {}
        self.namespace = namespace

    def _qual(self, name: str) -> str:
        return f"{self.namespace}/{name}" if self.namespace else name

    def register(
        self,
        name: str,
        fn: Callable,
        *,
        static_argnums: Sequence[int] = (),
        donate_argnums: Sequence[int] = (),
    ) -> Callable:
        if name in self._fns:
            raise ValueError(f"program {name!r} already registered")
        jitted = jax.jit(
            fn,
            static_argnums=tuple(static_argnums),
            donate_argnums=tuple(donate_argnums),
        )
        self._fns[name] = jitted
        return jitted

    def _count(self, fn) -> int:
        sz = getattr(fn, "_cache_size", None)
        if sz is None:  # older jax: no observability, report 0 not a crash
            return 0
        return int(sz())

    def sizes(self) -> Dict[str, int]:
        """Compiled-variant count per registered program (namespace-qualified
        names when a namespace is set)."""
        return {self._qual(name): self._count(fn) for name, fn in self._fns.items()}

    def total(self) -> int:
        return sum(self.sizes().values())

    def snapshot(self) -> Dict[str, int]:
        """Alias of :meth:`sizes` named for the churn-accounting idiom."""
        return self.sizes()

    def misses_since(self, snap: Dict[str, int]) -> Dict[str, int]:
        """New compilations per program since ``snap`` (missing names count
        from zero)."""
        now = self.sizes()
        return {
            name: now[name] - snap.get(name, 0)
            for name in now
            if now[name] - snap.get(name, 0)
        }
