"""Serving engines with first-class GLASS integration.

Two engines share the same model API and GLASS pipeline:

``Engine`` — static batch (the original demo path): every request arrives
together, shares one prompt padding, and finishes together; masks are built
once for the whole batch.

``ContinuousEngine`` — continuous batching (the production path): a
``Scheduler`` queues requests, a ``KVPool`` holds a fixed slot arena, and
each request owns *per-slot* GLASS state — its own prefill-local stats,
fused mask, and compact-or-masked FFN weights, exactly the paper's
per-prompt dynamic selection.  Prefill is interleaved with ongoing decode;
finished sequences are evicted and their slots reused without recompiling
(decode is one jitted step over the full arena, per-slot lengths mask the
frontier).

Request lifecycle (paper Fig. 2 right), per slot in the continuous case:

  1. prefill the prompt, collecting local activation stats;
  2. fuse local stats with the offline global prior -> per-layer masks;
  3. gather compact FFN weights once, into the slot's row;
  4. steady-state decode with the compact weights (density * FLOPs/bytes).

``PagedEngine`` — the paged refactor of the continuous engine: a
``BlockPool`` block table replaces the slot arena (a request's KV footprint
is ``ceil(rows / block_size)`` blocks, not ``max_len``), prompts are
prefilled in bounded-token *chunks* interleaved with decode ticks (GLASS
local stats accumulate across chunks; the fused mask is finalized at the
last chunk), and admission follows a selectable ``AdmissionPolicy``.

``glass=None`` serves dense.  ``mode="masked"`` keeps full weights and
multiplies the mask in; ``"compact"`` gathers (the fast-memory-residency
deployment); ``"block_sparse"`` (with ``selection="block"``) feeds each
slot's active block list to the pallas ``glass_ffn`` kernel — the TPU-native
execution of the mask, reading only active weight tiles from HBM.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fusion import GlassConfig
from ..core.glass import build_masks, compact_params
from ..models.api import Model
from .kv_pool import BlockPool, KVPool, clear_slot_leaf
from .sampling import sample
from .scheduler import AdmissionPolicy, FinishedRequest, Request, Scheduler


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, max_new)
    logits_seq: Optional[np.ndarray]  # (B, max_new, V) when requested
    masks: Optional[object]


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked | block_sparse
    ):
        self.model = model
        # jitted callables keyed by static call signature: repeated generate()
        # calls with the same shapes must NOT re-trace (masks/compact weights
        # are traced arguments, so per-request GLASS state reuses the cache)
        self._jits: Dict[tuple, object] = {}
        self.params = params  # via the setter: owns _jits invalidation
        self.glass = glass
        self.prior = global_prior
        self.glass_mode = glass_mode
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if glass_mode == "block_sparse":
            assert glass is None or glass.selection == "block", \
                "block_sparse mode needs block-structured selection"
        if glass is not None and glass_mode == "compact" and glass.selection == "block":
            raise ValueError(
                "block selection yields block ids, not unit indices — "
                "use glass_mode='masked' or 'block_sparse' with it"
            )

    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, new):
        # evict the jit cache when the weights change identity: entries are
        # keyed only on call signature, so a stale executable could otherwise
        # keep serving donated/retained buffers from the previous weights
        if new is not getattr(self, "_params", None):
            self._jits.clear()
        self._params = new

    def _prefill_fn(self, B: int, S: int, max_len: int):
        key = ("prefill", B, S, max_len)
        if key not in self._jits:
            model = self.model
            self._jits[key] = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, max_len))
        return self._jits[key]

    def _decode_fn(self, B: int, S: int, max_new: int, temperature: float, top_k: int,
                   return_logits: bool):
        key = ("decode", B, S, max_new, temperature, top_k, return_logits)
        if key not in self._jits:
            model = self.model

            bsz = self.glass.block_size if self.glass is not None else 128

            def pick(r, lg):
                if temperature <= 0.0:
                    return jnp.argmax(lg, axis=-1).astype(jnp.int32)
                return sample(r, lg, temperature=temperature, top_k=top_k).astype(jnp.int32)

            def decode_loop(params, cache, first_tok, rng, ffn_masks, compact, block_idx):
                def body(carry, i):
                    cache, tok, rng = carry
                    rng, krng = jax.random.split(rng)
                    lg, cache = model.decode_step(
                        params, tok[:, None], cache, S + i,
                        ffn_masks=ffn_masks, compact_layers=compact,
                        ffn_block_idx=block_idx, ffn_block_size=bsz,
                    )
                    nxt = pick(krng, lg[:, -1].astype(jnp.float32))
                    return (cache, nxt, rng), (nxt, lg[:, -1] if return_logits else jnp.zeros((B, 0)))

                (_, _, _), (toks, lgs) = jax.lax.scan(
                    body, (cache, first_tok, rng), jnp.arange(max_new, dtype=jnp.int32)
                )
                return toks.T, jnp.swapaxes(lgs, 0, 1)

            self._jits[key] = jax.jit(decode_loop)
        return self._jits[key]

    def generate(
        self,
        prompts: jax.Array,  # (B, S) int32, right-aligned/padded by caller
        max_new: int,
        *,
        rng: Optional[jax.Array] = None,
        temperature: float = 0.0,  # 0 => greedy
        top_k: int = 0,
        return_logits: bool = False,
    ) -> GenerationResult:
        model, params = self.model, self.params
        B, S = prompts.shape
        logits, cache, stats = self._prefill_fn(B, S, S + max_new)(params, prompts)

        masks = None
        compact = None
        ffn_masks = None
        block_idx = None
        if self.glass is not None:
            masks = build_masks(stats, self.prior, self.glass)
            if self.glass_mode == "compact":
                compact = compact_params(model, params, masks.idx)
            elif self.glass_mode == "block_sparse":
                block_idx = masks.idx  # (L, nb_keep) active block ids
            else:
                ffn_masks = masks.mask

        rng = rng if rng is not None else jax.random.key(0)
        rng, krng = jax.random.split(rng)
        if temperature <= 0.0:
            first = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1).astype(jnp.int32)
        else:
            first = sample(krng, logits[:, -1].astype(jnp.float32),
                           temperature=temperature, top_k=top_k).astype(jnp.int32)
        decode_loop = self._decode_fn(B, S, max_new, temperature, top_k, return_logits)
        toks, lgs = decode_loop(params, cache, first, rng, ffn_masks, compact, block_idx)
        out_tokens = np.asarray(jnp.concatenate([first[:, None], toks[:, :-1]], axis=1))
        return GenerationResult(
            tokens=out_tokens,
            logits_seq=np.asarray(lgs) if return_logits else None,
            masks=masks,
        )


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class GlassSlotState:
    """Per-slot GLASS state arenas for the continuous engine.

    ``masked`` keeps a float mask arena ((L, max_slots, m); MoE adds the
    expert axis, the hybrid shared block drops L).  ``compact`` keeps the
    per-slot stacked compact-weight pytree from ``compact_params`` with the
    slot axis sized ``max_slots``.  Arenas are created lazily on the first
    admission (that fixes every shape) and rows are overwritten/zeroed as
    slots turn over.  Multiple admissions in one step are fused into a
    single ``build_masks(..., slot_axis=True)`` + ``compact_params`` call.
    """

    def __init__(self, model: Model, params, gcfg: GlassConfig, prior, mode: str, max_slots: int):
        if mode not in ("masked", "compact", "block_sparse"):
            raise ValueError(mode)
        if mode == "block_sparse":
            if model.cfg.family not in ("dense", "vlm"):
                raise NotImplementedError("block-sparse decode targets dense-FFN families")
            if gcfg.selection != "block":
                raise ValueError("block_sparse mode needs GlassConfig(selection='block')")
        if mode == "compact" and gcfg.selection == "block":
            raise ValueError(
                "block selection yields block ids, not unit indices — "
                "use glass_mode='masked' or 'block_sparse' with it"
            )
        self.model = model
        self.params = params
        self.gcfg = gcfg
        self.prior = prior
        self.mode = mode
        self.max_slots = max_slots
        # slot axis in both the stacked rows and the arena: after the leading
        # L axis everywhere except hybrid compact weights (no L axis at all)
        self.slot_axis = 0 if (model.cfg.family == "hybrid" and mode == "compact") else 1
        self.arena = None
        ax = self.slot_axis

        def write(arena, rows, slots):
            # one scatter for ALL slots admitted this tick (slots (B,) int32)
            def one(a, r):
                r = r.astype(a.dtype)
                return a.at[slots].set(r) if ax == 0 else a.at[:, slots].set(r)

            return jax.tree.map(one, arena, rows)

        def clear(arena, slot):
            return jax.tree.map(lambda a: clear_slot_leaf(a, ax, slot), arena)

        def rows(params, prior, stacked):
            ms = build_masks(stacked, prior, gcfg, slot_axis=True)
            if mode == "masked":
                # hybrid keeps the (1, B, m) MaskSet layout: rank (not shape)
                # distinguishes per-slot from the legacy shared (1, m) mask
                return ms.mask  # (L, B, m) / (L, B, E, f) / hybrid (1, B, m)
            if mode == "block_sparse":
                return ms.idx  # (L, B, nb_keep) int32 active block ids
            return compact_params(model, params, ms.idx)

        # jitted like KVPool's writers: admission-path mask fusion and
        # compaction, and slot writes/clears, must not dispatch eagerly; the
        # arena argument is dead after each call, so donate it
        self._rows = jax.jit(rows)
        self._write = jax.jit(write, donate_argnums=(0,))
        self._clear = jax.jit(clear, donate_argnums=(0,))

    def admit(self, slots: List[int], stats_list) -> None:
        ax = self.slot_axis
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stats_list)
        rows = self._rows(self.params, self.prior, stacked)
        if self.arena is None:
            self.arena = jax.tree.map(
                lambda r: jnp.zeros(r.shape[:ax] + (self.max_slots,) + r.shape[ax + 1 :], r.dtype),
                rows,
            )
        self.arena = self._write(self.arena, rows, jnp.asarray(slots, jnp.int32))

    def clear(self, slot: int) -> None:
        """Zero the slot's row.  A zero mask / zero compact gather makes the
        FFN contribution of an inactive slot exactly zero — cheap hygiene on
        top of the engine never reading inactive slots' logits."""
        if self.arena is None:
            return
        self.arena = self._clear(self.arena, jnp.int32(slot))


class _QueueEngineBase:
    """Shared host-side plumbing for the queue-driven engines: submission,
    first-token sampling, finish bookkeeping, and the drain loop.
    Subclasses provide ``step()`` (one tick group) and ``_drain_budget()``
    (a safe upper bound on ticks to drain the current workload), and may
    hook ``_on_free`` for extra per-slot teardown."""

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    @property
    def n_active(self) -> int:
        return int(self.pool.active.sum())

    def _first_token(self, logits_last: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_last))
        self._rng, krng = jax.random.split(self._rng)
        return int(
            sample(krng, jnp.asarray(logits_last)[None], temperature=self.temperature,
                   top_k=self.top_k)[0]
        )

    def _on_free(self, slot: int) -> None:
        pass

    def _finish(self, slot: int, finished: List[FinishedRequest]) -> None:
        r = self.live[slot]
        finished.append(
            FinishedRequest(
                uid=r.uid,
                prompt=np.asarray(r.prompt, np.int32),
                tokens=np.asarray(self.outputs[slot], np.int32),
                arrival=r.arrival,
                admitted_step=self.admitted_step[slot],
                finished_step=self.t,
            )
        )
        self.pool.free(slot)
        if self.glass_slots is not None:
            self.glass_slots.clear(slot)
        self.live[slot] = None
        self.outputs[slot] = None
        self.pending[slot] = 0
        self._on_free(slot)

    def run(self, requests=(), max_steps: Optional[int] = None) -> Dict[int, FinishedRequest]:
        """Serve until queue and slots drain; returns {uid: FinishedRequest}."""
        for r in requests:
            self.submit(r)  # the subclass's validation applies
        if max_steps is None:
            queued = list(self.scheduler.queue)
            live = [r for r in self.live if r is not None]
            budget = self._drain_budget(queued, live)
            arrivals = [r.arrival for r in queued] + [0]
            max_steps = self.t + max(arrivals) + budget + len(queued) + self.pool.max_slots + 8
        done: Dict[int, FinishedRequest] = {}
        while len(self.scheduler) or self.pool.active.any():
            if self.t > max_steps:
                raise RuntimeError(
                    f"{type(self).__name__} did not drain in {max_steps} steps"
                )
            for f in self.step():
                done[f.uid] = f
        return done


class ContinuousEngine(_QueueEngineBase):
    """Continuous-batching server: admit-as-slots-free, decode over a fixed
    arena, evict on completion.

    Greedy by default (``temperature=0``); with a temperature the sampled
    stream is deterministic given ``rng`` but not token-compatible with the
    static ``Engine`` (different rng consumption order).
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked
        temperature: float = 0.0,
        top_k: int = 0,
        rng: Optional[jax.Array] = None,
        decode_chunk: int = 8,  # max ticks fused into one jitted scan
    ):
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if model.cfg.is_encoder_decoder:
            raise NotImplementedError("continuous batching targets decoder LMs")
        self.model = model
        self.params = params
        self.temperature = temperature
        self.top_k = top_k
        self.pool = KVPool(model, max_slots, max_len)
        self.scheduler = Scheduler(max_len)
        self.glass_slots = (
            GlassSlotState(model, params, glass, global_prior, glass_mode, max_slots)
            if glass is not None
            else None
        )
        self.pending = np.zeros((max_slots,), np.int32)  # next token to feed, per slot
        self.outputs: List[Optional[List[int]]] = [None] * max_slots
        self.live: List[Optional[Request]] = [None] * max_slots
        self.admitted_step = [0] * max_slots
        self.t = 0  # engine step counter == decode ticks
        self.slot_steps = 0  # decode ticks x active slots (scheduling telemetry)
        self._rng = rng if rng is not None else jax.random.key(0)

        # prefill at the request's exact length (jit caches per length); the
        # cache is sized to the prompt so the pool insert stays minimal
        self._prefill = jax.jit(lambda pr, tk: model.prefill(pr, {"tokens": tk}, tk.shape[1]))

        mode = self.glass_slots.mode if self.glass_slots is not None else None
        # fused-decode horizon: whenever the scheduler can prove no admission
        # or eviction can happen for H ticks, H decode steps run as ONE jitted
        # scan — the host round-trip (the dominant per-token cost at small
        # scale) is paid once per chunk instead of once per token.  H is
        # bucketed to powers of two so at most log2(chunk)+1 variants compile.
        self.decode_chunk = max(1, decode_chunk)

        bsz = glass.block_size if glass is not None else 128

        def dec(pr, cache, lengths, toks, extra, rng, H):
            kw = {}
            if mode == "masked":
                kw["ffn_masks"] = extra
            elif mode == "compact":
                kw["compact_layers"] = extra
            elif mode == "block_sparse":
                kw["ffn_block_idx"] = extra
                kw["ffn_block_size"] = bsz

            def body(carry, _):
                cache, lengths, toks, rng = carry
                lg, cache = model.decode_step(pr, toks[:, None], cache, lengths, **kw)
                lg = lg[:, -1].astype(jnp.float32)
                rng, krng = jax.random.split(rng)
                if temperature > 0.0:
                    nxt = sample(krng, lg, temperature=temperature, top_k=top_k)
                else:
                    nxt = jnp.argmax(lg, axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (cache, lengths + 1, nxt, rng), nxt

            (cache, _, _, rng), seq = jax.lax.scan(
                body, (cache, lengths, toks, rng), None, length=H
            )
            return seq, cache, rng  # seq (H, B)

        # the arena is dead after each chunk — donate it so XLA updates the
        # KV cache in place instead of copying max_slots * max_len every tick
        self._decode = jax.jit(dec, static_argnums=(6,), donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def _horizon(self) -> int:
        """Largest safe fused-decode length: bounded by the first possible
        eviction (min remaining tokens of any active slot) and — when a free
        slot could accept it — the next queued arrival.  Bucketed to a power
        of two so the chunked decode compiles O(log chunk) variants."""
        active = np.nonzero(self.pool.active)[0]
        h = min(self.live[int(s)].max_new - len(self.outputs[int(s)]) for s in active)
        if self.pool.n_free and len(self.scheduler):
            na = self.scheduler.next_arrival()
            if na is not None:  # all remaining arrivals are in the future
                h = min(h, na - self.t)
        h = min(h, self.decode_chunk)
        p = 1
        while p * 2 <= h:
            p *= 2
        return p

    def step(self) -> List[FinishedRequest]:
        """One engine tick group: admit arrived requests into free slots
        (prefill interleaved with decode), then decode the largest provably
        safe chunk of tokens for every active slot.  Returns requests
        finished in this group."""
        finished: List[FinishedRequest] = []
        reqs = self.scheduler.pop_admissible(self.t, self.pool.n_free)
        if reqs:
            self._admit(reqs, finished)
        if self.pool.active.any():
            H = self._horizon()
            extra = self.glass_slots.arena if self.glass_slots is not None else None
            seq, cache, self._rng = self._decode(
                self.params,
                self.pool.cache,
                jnp.asarray(self.pool.lengths),
                jnp.asarray(self.pending),
                extra,
                self._rng,
                H,
            )
            self.pool.cache = cache
            seq = np.asarray(seq)  # (H, B)
            self.slot_steps += H * int(self.pool.active.sum())
            for s in np.nonzero(self.pool.active)[0]:
                s = int(s)
                self.pool.lengths[s] += H
                self.outputs[s].extend(int(x) for x in seq[:, s])
                self.pending[s] = seq[-1, s]
                if len(self.outputs[s]) >= self.live[s].max_new:
                    self._finish(s, finished)
            self.t += H
        else:
            na = self.scheduler.next_arrival()
            # idle: fast-forward to the next arrival instead of spinning
            self.t = max(self.t + 1, na if na is not None else self.t + 1)
        return finished

    def _drain_budget(self, queued: List[Request], live: List[Request]) -> int:
        return sum(r.max_new for r in queued) + sum(r.max_new for r in live)

    # -- internals ----------------------------------------------------------

    def _admit(self, reqs: List[Request], finished: List[FinishedRequest]) -> None:
        slots, stats_list = [], []
        for r in reqs:
            slot = self.pool.alloc()
            toks = jnp.asarray(np.asarray(r.prompt, np.int32))[None]
            logits, cache, stats = self._prefill(self.params, toks)
            first = self._first_token(np.asarray(logits[0, -1], np.float32))
            self.pool.write_prefill(slot, cache, len(r.prompt))
            self.pending[slot] = first
            self.outputs[slot] = [first]
            self.live[slot] = r
            self.admitted_step[slot] = self.t
            slots.append(slot)
            stats_list.append(stats)
        if self.glass_slots is not None:
            self.glass_slots.admit(slots, stats_list)
        for slot in slots:  # max_new == 1 completes without a decode tick
            if len(self.outputs[slot]) >= self.live[slot].max_new:
                self._finish(slot, finished)


# ---------------------------------------------------------------------------
# Paged continuous batching (block table + chunked prefill)
# ---------------------------------------------------------------------------


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to [1, cap]."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class PagedEngine(_QueueEngineBase):
    """Continuous batching over a paged KV block table with chunked prefill.

    Differences vs :class:`ContinuousEngine` (which is kept as the
    slot-arena reference — both are greedy-token-identical to single-request
    serving):

      * **memory** — a :class:`BlockPool`: each request holds
        ``ceil((len(prompt) + max_new - 1) / block_size)`` KV blocks from a
        shared pool instead of a private ``max_len`` arena row, so the pool
        is sized for the *expected total* tokens in flight, not
        ``max_slots`` worst cases.  Recurrent state stays per-slot.
      * **prefill** — prompts are processed in chunks of at most
        ``chunk_tokens`` per engine tick, writing straight into the
        request's blocks and accumulating GLASS local stats; decode ticks
        interleave between chunks, so admission never stalls decode for
        longer than one chunk regardless of prompt length.  The fused mask
        (and compact weights / block list) is built once, at the final
        chunk — identical to a single-shot prefill because the stats are
        running sums.
      * **decode** — one jitted step over the fixed ``max_slots`` decode
        batch reading through the block table, with the gather width
        bucketed to the longest *active* request (powers of two), so
        short-context phases don't pay ``max_len`` attention.  Free and
        mid-prefill rows point at the reserved trash block 0 with length 0:
        their (masked, never-read) writes stay off live blocks.
      * **admission** — ``AdmissionPolicy`` (FIFO / priority / deadline),
        best-effort under block availability.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        chunk_tokens: int = 32,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked | block_sparse
        policy: AdmissionPolicy = AdmissionPolicy.FIFO,
        temperature: float = 0.0,
        top_k: int = 0,
        rng: Optional[jax.Array] = None,
        decode_chunk: int = 8,  # max ticks fused into one jitted scan
    ):
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if model.cfg.is_encoder_decoder:
            raise NotImplementedError("continuous batching targets decoder LMs")
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        self.model = model
        self.params = params
        self.temperature = temperature
        self.top_k = top_k
        self.chunk_tokens = chunk_tokens
        self.pool = BlockPool(model, max_slots, max_len, block_size, num_blocks)
        self.scheduler = Scheduler(max_len, policy=policy)
        self.glass = glass
        self.glass_slots = (
            GlassSlotState(model, params, glass, global_prior, glass_mode, max_slots)
            if glass is not None
            else None
        )
        self.pending = np.zeros((max_slots,), np.int32)  # next token to feed, per slot
        self.outputs: List[Optional[List[int]]] = [None] * max_slots
        self.live: List[Optional[Request]] = [None] * max_slots
        self.admitted_step = [0] * max_slots
        # prompt tokens already prefilled; -1 = prefill done, slot decoding
        self.prefill_pos = np.full((max_slots,), -1, np.int32)
        self._pstats: List[Optional[object]] = [None] * max_slots
        self.t = 0
        self.slot_steps = 0  # decode ticks x decoding slots (scheduling telemetry)
        self.kv_row_ticks = 0  # allocated KV rows x ticks (memory telemetry)
        self.max_prefill_tokens_per_tick = 0
        self.decode_chunk = max(1, decode_chunk)
        self._rng = rng if rng is not None else jax.random.key(0)

        mode = self.glass_slots.mode if self.glass_slots is not None else None
        self._mode = mode
        bsz = glass.block_size if glass is not None else 128
        has_paged = self.pool.has_paged
        axes_t, paged_t = self.pool.axes, self.pool.paged
        has_state = not all(jax.tree.leaves(self.pool.paged))

        def dec(pr, arena, lengths, toks, btab, dmask, extra, rng, H):
            kw = {}
            if mode == "masked":
                kw["ffn_masks"] = extra
            elif mode == "compact":
                kw["compact_layers"] = extra
            elif mode == "block_sparse":
                kw["ffn_block_idx"] = extra
                kw["ffn_block_size"] = bsz
            if has_paged:
                kw["block_table"] = btab

            def guard(old, new, ax, pg):
                # recurrent-state rows of non-decoding slots (free, or holding
                # a mid-prefill request whose state IS the live prefill carry)
                # must not absorb the dummy-token recurrence; paged KV writes
                # are already scoped to live blocks by the trash-block table
                if pg:
                    return new
                m = dmask.reshape((1,) * ax + (-1,) + (1,) * (old.ndim - ax - 1))
                return jnp.where(m, new, old)

            def body(carry, _):
                arena, lengths, toks, rng = carry
                lg, new = model.decode_step(pr, toks[:, None], arena, lengths, **kw)
                arena = jax.tree.map(guard, arena, new, axes_t, paged_t) if has_state else new
                lg = lg[:, -1].astype(jnp.float32)
                rng, krng = jax.random.split(rng)
                if temperature > 0.0:
                    nxt = sample(krng, lg, temperature=temperature, top_k=top_k)
                else:
                    nxt = jnp.argmax(lg, axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (arena, lengths + 1, nxt, rng), nxt

            (arena, _, _, rng), seq = jax.lax.scan(
                body, (arena, lengths, toks, rng), None, length=H
            )
            return seq, arena, rng  # seq (H, B)

        # the arena is dead after each call — donate so the block pool (and
        # state rows) update in place instead of copying every tick
        self._decode = jax.jit(dec, static_argnums=(8,), donate_argnums=(1,))

        axes, paged = self.pool.axes, self.pool.paged

        def chunk(pr, arena, toks, clen, btab, slot):
            # state leaves: slice this slot's rows out of the arena; paged
            # leaves pass through whole (the block table scopes the access)
            def take(a, ax, pg):
                return a if pg else jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            rows = jax.tree.map(take, arena, axes, paged)
            logits, new, stats = model.prefill_chunk(
                pr, toks, rows, clen, block_table=btab if has_paged else None
            )

            def put(a, n, ax, pg):
                if pg:
                    return n
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, n.astype(a.dtype), starts)

            arena = jax.tree.map(put, arena, new, axes, paged)
            return logits[:, -1], arena, stats

        self._chunk = jax.jit(chunk, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = self.pool.blocks_needed(self._rows_needed(req))
        if self.pool.has_paged and need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request {req.uid} needs {need} blocks > pool capacity "
                f"{self.pool.num_blocks - 1}"
            )
        super().submit(req)

    def _drain_budget(self, queued: List[Request], live: List[Request]) -> int:
        chunks = self.chunk_tokens
        return sum(r.max_new + -(-len(r.prompt) // chunks) for r in queued + live)

    def _rows_needed(self, r: Request) -> int:
        return len(r.prompt) + r.max_new - 1

    def _decoding(self) -> np.ndarray:
        return np.nonzero(self.pool.active & (self.prefill_pos < 0))[0]

    def _prefilling(self) -> List[int]:
        return [int(s) for s in np.nonzero(self.pool.active & (self.prefill_pos >= 0))[0]]

    # -- internals ----------------------------------------------------------

    def _admit(self) -> None:
        while self.pool.n_free_slots:
            got = self.scheduler.pop_admissible(
                self.t, 1, fits=lambda r: self.pool.fits(self._rows_needed(r))
            )
            if not got:
                return
            r = got[0]
            slot = self.pool.admit(self._rows_needed(r))
            assert slot is not None  # fits() held and a slot was free
            self.live[slot] = r
            self.outputs[slot] = None
            self.pending[slot] = 0
            self.prefill_pos[slot] = 0
            self._pstats[slot] = None
            self.admitted_step[slot] = self.t

    def _prefill_tick(self, finished: List[FinishedRequest]) -> bool:
        """Run ONE bounded chunk for the oldest mid-prefill request."""
        pre = self._prefilling()
        if not pre:
            return False
        slot = min(pre, key=lambda s: (self.admitted_step[s], s))
        r = self.live[slot]
        pos = int(self.prefill_pos[slot])
        T = min(self.chunk_tokens, len(r.prompt) - pos)
        toks = jnp.asarray(np.asarray(r.prompt[pos : pos + T], np.int32))[None]
        # gather width covers the *prefilled prefix* (every page written so
        # far plus this chunk), not the request's full allocation — early
        # chunks of a long-generation request must not attend max_len rows
        nb = _pow2_bucket(-(-(pos + T) // self.pool.block_size), self.pool.nb_max)
        btab = jnp.asarray(self.pool.block_table[slot : slot + 1, :nb])
        last, arena, stats = self._chunk(
            self.params, self.pool.cache, toks, jnp.asarray([pos], jnp.int32),
            btab, jnp.int32(slot),
        )
        self.pool.cache = arena
        self.pool.lengths[slot] = pos + T
        self.prefill_pos[slot] = pos + T
        self._pstats[slot] = (
            stats if self._pstats[slot] is None
            else jax.tree.map(lambda a, b: a + b, self._pstats[slot], stats)
        )
        self.max_prefill_tokens_per_tick = max(self.max_prefill_tokens_per_tick, T)
        if pos + T == len(r.prompt):  # final chunk: finalize GLASS + first token
            if self.glass_slots is not None:
                self.glass_slots.admit([slot], [self._pstats[slot]])
            self._pstats[slot] = None
            first = self._first_token(np.asarray(last[0], np.float32))
            self.outputs[slot] = [first]
            self.pending[slot] = first
            self.prefill_pos[slot] = -1
            if len(self.outputs[slot]) >= r.max_new:
                self._finish(slot, finished)
        return True

    def _horizon(self, prefill_pending: bool) -> int:
        """Largest safe fused-decode length: 1 while any prefill is pending
        (chunks must interleave), else bounded by the first possible eviction
        and — when capacity could accept it — the next queued arrival."""
        if prefill_pending:
            return 1
        dec = self._decoding()
        h = min(self.live[int(s)].max_new - len(self.outputs[int(s)]) for s in dec)
        if self.pool.n_free_slots and len(self.scheduler):
            # only arrivals that could actually be admitted bound the chunk:
            # an arrived-but-unfitting request (block pressure) can only be
            # admitted after an eviction, and h is already bounded by the
            # first eviction — clamping on it would degrade decode to H=1
            na = min(
                (r.arrival for r in self.scheduler.queue
                 if self.pool.fits(self._rows_needed(r))),
                default=None,
            )
            if na is not None:
                h = min(h, max(1, na - self.t))
        h = min(h, self.decode_chunk)
        p = 1
        while p * 2 <= h:
            p *= 2
        return p

    def _decode_tick(self, finished: List[FinishedRequest], prefill_pending: bool) -> bool:
        dec = self._decoding()
        if dec.size == 0:
            return False
        H = self._horizon(prefill_pending)
        decoding = np.zeros((self.pool.max_slots,), bool)
        decoding[dec] = True
        lengths = np.where(decoding, self.pool.lengths, 0).astype(np.int32)
        toks = np.where(decoding, self.pending, 0).astype(np.int32)
        if self.pool.has_paged:
            need = int(max(lengths[s] + H for s in dec))
            nb = _pow2_bucket(-(-need // self.pool.block_size), self.pool.nb_max)
            btab = np.where(
                decoding[:, None], self.pool.block_table[:, :nb], 0
            ).astype(np.int32)
        else:
            btab = np.zeros((self.pool.max_slots, 1), np.int32)
        extra = self.glass_slots.arena if self.glass_slots is not None else None
        seq, arena, self._rng = self._decode(
            self.params, self.pool.cache, jnp.asarray(lengths), jnp.asarray(toks),
            jnp.asarray(btab), jnp.asarray(decoding), extra, self._rng, H,
        )
        self.pool.cache = arena
        seq = np.asarray(seq)  # (H, B)
        self.slot_steps += H * int(dec.size)
        for s in dec:
            s = int(s)
            self.pool.lengths[s] += H
            self.outputs[s].extend(int(x) for x in seq[:, s])
            self.pending[s] = seq[-1, s]
            if len(self.outputs[s]) >= self.live[s].max_new:
                self._finish(s, finished)
        self.t += H
        return True

    def step(self) -> List[FinishedRequest]:
        """One engine tick group: admit (policy order, best-effort under
        block availability), run at most one bounded prefill chunk, then
        decode the largest provably safe fused chunk."""
        finished: List[FinishedRequest] = []
        t0 = self.t
        self._admit()
        prefilled = self._prefill_tick(finished)
        self._admit()  # a finished max_new==1 request may have freed capacity
        # memory telemetry: blocks held by every in-flight request (decoding
        # AND mid-prefill) integrate over every tick this step advances
        rows_now = self.pool.blocks_in_use * self.pool.block_size
        prefill_pending = bool(self._prefilling())
        decoded = self._decode_tick(finished, prefill_pending or prefilled)
        if not decoded:
            if prefilled:
                self.t += 1
            else:
                na = self.scheduler.next_arrival()
                self.t = max(self.t + 1, na if na is not None else self.t + 1)
        self.kv_row_ticks += (self.t - t0) * rows_now
        return finished

    def _on_free(self, slot: int) -> None:
        self.prefill_pos[slot] = -1
        self._pstats[slot] = None
