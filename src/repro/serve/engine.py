"""Serving engines with first-class GLASS integration.

Two engines share the same model API and GLASS pipeline:

``Engine`` — static batch (the original demo path): every request arrives
together, shares one prompt padding, and finishes together; masks are built
once for the whole batch.

``ContinuousEngine`` — continuous batching (the production path): a
``Scheduler`` queues requests, a ``KVPool`` holds a fixed slot arena, and
each request owns *per-slot* GLASS state — its own prefill-local stats,
fused mask, and compact-or-masked FFN weights, exactly the paper's
per-prompt dynamic selection.  Prefill is interleaved with ongoing decode;
finished sequences are evicted and their slots reused without recompiling
(decode is one jitted step over the full arena, per-slot lengths mask the
frontier).

Request lifecycle (paper Fig. 2 right), per slot in the continuous case:

  1. prefill the prompt, collecting local activation stats;
  2. fuse local stats with the offline global prior -> per-layer masks;
  3. gather compact FFN weights once, into the slot's row;
  4. steady-state decode with the compact weights (density * FLOPs/bytes).

``PagedEngine`` — the paged refactor of the continuous engine: a
``BlockPool`` block table replaces the slot arena (a request's KV footprint
is ``ceil(rows / block_size)`` blocks, not ``max_len``), prompts are
prefilled in bounded-token *chunks* interleaved with decode ticks (GLASS
local stats accumulate across chunks; the fused mask is finalized at the
last chunk), and admission follows a selectable ``AdmissionPolicy``.

``glass=None`` serves dense.  ``mode="masked"`` keeps full weights and
multiplies the mask in; ``"compact"`` gathers (the fast-memory-residency
deployment); ``"block_sparse"`` (with ``selection="block"``) feeds each
slot's active block list to the pallas ``glass_ffn`` kernel — the TPU-native
execution of the mask, reading only active weight tiles from HBM.
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fusion import GlassConfig, merge_stat_sums
from ..core.glass import (
    GlassParams,
    build_masks,
    build_tiered_masks,
    compact_params,
    reselect_at_density,
    restore_stat_sums,
    snapshot_stat_sums,
)
from ..models.api import Model
from .kv_pool import (
    BlockPool,
    KVPool,
    SwappedWire,
    clear_slot_leaf,
    pow2_bucket as _pow2_bucket,
)
from .lifecycle import (
    Lifecycle,
    LiveRequest,
    PreemptionConfig,
    ReqState,
    SpecCheckpoint,
    preemption_kind,
)
from .programs import ProgramCache
from .sampling import MAX_STOP_IDS, SamplingParams, sample, sample_positional
from .scheduler import (
    AdmissionPolicy,
    FinishedRequest,
    Request,
    RequestOutput,
    Scheduler,
)


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, max_new)
    logits_seq: Optional[np.ndarray]  # (B, max_new, V) when requested
    masks: Optional[object]


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked | block_sparse
    ):
        self.model = model
        # jitted callables keyed by static call signature: repeated generate()
        # calls with the same shapes must NOT re-trace (masks/compact weights
        # are traced arguments, so per-request GLASS state reuses the cache)
        self._jits: Dict[tuple, object] = {}
        self.params = params  # via the setter: owns _jits invalidation
        self.glass = glass
        self.prior = global_prior
        self.glass_mode = glass_mode
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if glass_mode == "block_sparse":
            assert glass is None or glass.selection == "block", \
                "block_sparse mode needs block-structured selection"
        if glass is not None and glass_mode == "compact" and glass.selection == "block":
            raise ValueError(
                "block selection yields block ids, not unit indices — "
                "use glass_mode='masked' or 'block_sparse' with it"
            )

    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, new):
        # evict the jit cache when the weights change identity: entries are
        # keyed only on call signature, so a stale executable could otherwise
        # keep serving donated/retained buffers from the previous weights
        if new is not getattr(self, "_params", None):
            self._jits.clear()
        self._params = new

    def _prefill_fn(self, B: int, S: int, max_len: int):
        key = ("prefill", B, S, max_len)
        if key not in self._jits:
            model = self.model
            self._jits[key] = jax.jit(lambda p, t: model.prefill(p, {"tokens": t}, max_len))
        return self._jits[key]

    def _decode_fn(self, B: int, S: int, max_new: int, temperature: float, top_k: int,
                   return_logits: bool):
        key = ("decode", B, S, max_new, temperature, top_k, return_logits)
        if key not in self._jits:
            model = self.model

            bsz = self.glass.block_size if self.glass is not None else 128

            def pick(r, lg):
                if temperature <= 0.0:
                    return jnp.argmax(lg, axis=-1).astype(jnp.int32)
                return sample(r, lg, temperature=temperature, top_k=top_k).astype(jnp.int32)

            def decode_loop(params, cache, first_tok, rng, ffn_masks, compact, block_idx):
                def body(carry, i):
                    cache, tok, rng = carry
                    rng, krng = jax.random.split(rng)
                    lg, cache = model.decode_step(
                        params, tok[:, None], cache, S + i,
                        ffn_masks=ffn_masks, compact_layers=compact,
                        ffn_block_idx=block_idx, ffn_block_size=bsz,
                    )
                    nxt = pick(krng, lg[:, -1].astype(jnp.float32))
                    return (cache, nxt, rng), (nxt, lg[:, -1] if return_logits else jnp.zeros((B, 0)))

                (_, _, _), (toks, lgs) = jax.lax.scan(
                    body, (cache, first_tok, rng), jnp.arange(max_new, dtype=jnp.int32)
                )
                return toks.T, jnp.swapaxes(lgs, 0, 1)

            self._jits[key] = jax.jit(decode_loop)
        return self._jits[key]

    def generate(
        self,
        prompts: jax.Array,  # (B, S) int32, right-aligned/padded by caller
        max_new: int,
        *,
        rng: Optional[jax.Array] = None,
        temperature: float = 0.0,  # 0 => greedy
        top_k: int = 0,
        return_logits: bool = False,
    ) -> GenerationResult:
        model, params = self.model, self.params
        B, S = prompts.shape
        logits, cache, stats = self._prefill_fn(B, S, S + max_new)(params, prompts)

        masks = None
        compact = None
        ffn_masks = None
        block_idx = None
        if self.glass is not None:
            masks = build_masks(stats, self.prior, self.glass)
            if self.glass_mode == "compact":
                compact = compact_params(model, params, masks.idx)
            elif self.glass_mode == "block_sparse":
                block_idx = masks.idx  # (L, nb_keep) active block ids
            else:
                ffn_masks = masks.mask

        rng = rng if rng is not None else jax.random.key(0)
        rng, krng = jax.random.split(rng)
        if temperature <= 0.0:
            first = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1).astype(jnp.int32)
        else:
            first = sample(krng, logits[:, -1].astype(jnp.float32),
                           temperature=temperature, top_k=top_k).astype(jnp.int32)
        decode_loop = self._decode_fn(B, S, max_new, temperature, top_k, return_logits)
        toks, lgs = decode_loop(params, cache, first, rng, ffn_masks, compact, block_idx)
        out_tokens = np.asarray(jnp.concatenate([first[:, None], toks[:, :-1]], axis=1))
        return GenerationResult(
            tokens=out_tokens,
            logits_seq=np.asarray(lgs) if return_logits else None,
            masks=masks,
        )


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class GlassSlotState:
    """Per-slot GLASS state arenas for the continuous engine.

    ``masked`` keeps a float mask arena ((L, max_slots, m); MoE adds the
    expert axis, the hybrid shared block drops L).  ``compact`` keeps the
    per-slot stacked compact-weight pytree from ``compact_params`` with the
    slot axis sized ``max_slots``.  Arenas are created lazily on the first
    admission (that fixes every shape) and rows are overwritten/zeroed as
    slots turn over.  Multiple admissions in one step are fused into a
    single ``build_masks(..., slot_axis=True)`` + ``compact_params`` call.
    """

    def __init__(self, model: Model, params, gcfg: GlassConfig, prior, mode: str, max_slots: int):
        if mode not in ("masked", "compact", "block_sparse"):
            raise ValueError(mode)
        if mode == "block_sparse":
            if model.cfg.family not in ("dense", "vlm"):
                raise NotImplementedError("block-sparse decode targets dense-FFN families")
            if gcfg.selection != "block":
                raise ValueError("block_sparse mode needs GlassConfig(selection='block')")
        if mode == "compact" and gcfg.selection == "block":
            raise ValueError(
                "block selection yields block ids, not unit indices — "
                "use glass_mode='masked' or 'block_sparse' with it"
            )
        self.model = model
        self.params = params
        self.gcfg = gcfg
        self.prior = prior
        self.mode = mode
        self.max_slots = max_slots
        # tiered (self-speculative) serving: a second arena holds the DRAFT
        # tier's rows — same selection machinery at density * draft_ratio,
        # built from the same fused scores so draft units nest in the target
        self.tiered = gcfg.draft_ratio is not None
        # slot axis in both the stacked rows and the arena: after the leading
        # L axis everywhere except hybrid compact weights (no L axis at all)
        self.slot_axis = 0 if (model.cfg.family == "hybrid" and mode == "compact") else 1
        self.arena = None
        self.draft_arena = None
        ax = self.slot_axis
        tiered = self.tiered

        def write(arena, rows, slots):
            # one scatter for ALL slots admitted this tick (slots (B,) int32)
            def one(a, r):
                r = r.astype(a.dtype)
                return a.at[slots].set(r) if ax == 0 else a.at[:, slots].set(r)

            return jax.tree.map(one, arena, rows)

        def clear(arena, slot):
            return jax.tree.map(lambda a: clear_slot_leaf(a, ax, slot), arena)

        def tier_rows(params, ms):
            if mode == "masked":
                # hybrid keeps the (1, B, m) MaskSet layout: rank (not shape)
                # distinguishes per-slot from the legacy shared (1, m) mask
                return ms.mask  # (L, B, m) / (L, B, E, f) / hybrid (1, B, m)
            if mode == "block_sparse":
                # (L, B, nb_keep) active block ids + per-(row, tile) f32
                # contribution multipliers: all-ones at the engine density
                # (1.0 * tile is bitwise the unscaled tile), zeros on tiles a
                # lower per-request density drops — see _override_fn
                return {
                    "idx": ms.idx,
                    "scale": jnp.ones(ms.idx.shape, jnp.float32),
                }
            return compact_params(model, params, ms.idx)

        def rows(params, prior, stacked):
            if tiered:
                ms, ds = build_tiered_masks(stacked, prior, gcfg, slot_axis=True)
                return tier_rows(params, ms), tier_rows(params, ds)
            ms = build_masks(stacked, prior, gcfg, slot_axis=True)
            return tier_rows(params, ms), None

        def save(arena, slot):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax), arena
            )

        # jitted like KVPool's writers: admission-path mask fusion and
        # compaction, and slot writes/clears, must not dispatch eagerly; the
        # arena argument is dead after each call, so donate it
        self._rows = jax.jit(rows)
        self._write = jax.jit(write, donate_argnums=(0,))
        self._clear = jax.jit(clear, donate_argnums=(0,))
        self._save = jax.jit(save)
        # per-request density variants (GlassParams): jit cache keyed on the
        # (density, draft_density) pair — bounded by the distinct densities
        # the engine actually serves
        self._override_jits: Dict[tuple, object] = {}

    def _override_fn(self, density: float, draft_density: Optional[float]):
        """Row builder for a request whose densities differ from the engine
        config.  The engine config is the CAPACITY tier: per-request
        selections at a lower density nest inside it (same fused scores,
        same stable tie-break), so

          * ``masked`` builds the float mask directly at the request's own
            density (the arena is density-agnostic);
          * ``compact`` gathers at the capacity tier and ZEROES the
            down-projection rows (``w_down`` / rwkv ``wv``) of units
            outside the request's own selection — the unit's contribution
            becomes exactly zero, so the fixed-``k`` arena row computes the
            request's lower-density FFN bit-for-bit;
          * ``block_sparse`` keeps the capacity tier's block LIST (the
            kernel grid width is fixed per arena) and sets the per-(row,
            tile) ``scale`` of blocks outside the request's nested
            reselection to exactly 0.0 — a zero contribution added to the
            kernel accumulator is bitwise a no-op, so the row computes the
            lower-density FFN exactly while the tiles are still streamed
            (I/O is traded for not recompiling per request).
        """
        key = (density, draft_density)
        fn = self._override_jits.get(key)
        if fn is not None:
            return fn
        model, gcfg, mode, tiered = self.model, self.gcfg, self.mode, self.tiered
        hybrid = model.cfg.family == "hybrid"

        def restrict(rows_dict, valid):
            # zero the down-projection rows of gathered units outside the
            # request's nested selection; every other leaf may stay — any
            # path through the unit ends in the zeroed projection
            if hybrid:
                valid = valid[0]  # compact_params drops the shared L=1 axis
            return {
                k2: (v * valid[..., None].astype(v.dtype)
                     if k2 in ("w_down", "wv") else v)
                for k2, v in rows_dict.items()
            }

        def one_compact_tier(params, ms_cap, cap_density, req_density):
            rows_t = compact_params(model, params, ms_cap.idx)
            if req_density < cap_density - 1e-12:
                req_mask = reselect_at_density(ms_cap, gcfg, req_density).mask
                valid = jnp.take_along_axis(req_mask, ms_cap.idx, axis=-1)
                rows_t = restrict(rows_t, valid)
            return rows_t

        def one_block_tier(ms_cap, cap_density, req_density):
            # the capacity tier's block ids keep the arena (and the kernel
            # grid) fixed-width; the request's own lower-density selection
            # NESTS inside it (same consensus scores, same stable
            # tie-break), so reading the request's unit mask at each listed
            # block's first unit yields exactly {0.0, 1.0} tile multipliers
            idx = ms_cap.idx
            scale = jnp.ones(idx.shape, jnp.float32)
            if req_density < cap_density - 1e-12:
                req_mask = reselect_at_density(ms_cap, gcfg, req_density).mask
                scale = jnp.take_along_axis(
                    req_mask, idx * gcfg.block_size, axis=-1
                ).astype(jnp.float32)
            return {"idx": idx, "scale": scale}

        def rows(params, prior, stacked):
            if mode == "masked":
                ms = build_masks(
                    stacked, prior,
                    replace(gcfg, density=density, draft_ratio=None),
                    slot_axis=True,
                )
                dmask = None
                if tiered:
                    dmask = reselect_at_density(ms, gcfg, draft_density).mask
                return ms.mask, dmask
            one_tier = (
                one_block_tier if mode == "block_sparse"
                else partial(one_compact_tier, params)
            )
            if tiered:
                ms_cap, ds_cap = build_tiered_masks(stacked, prior, gcfg,
                                                    slot_axis=True)
                tgt = one_tier(ms_cap, gcfg.density, density)
                dft = one_tier(
                    ds_cap, gcfg.density * gcfg.draft_ratio, draft_density
                )
                return tgt, dft
            ms_cap = build_masks(stacked, prior, gcfg, slot_axis=True)
            return one_tier(ms_cap, gcfg.density, density), None

        fn = jax.jit(rows)
        self._override_jits[key] = fn
        return fn

    def _init_arena(self, rows):
        ax = self.slot_axis
        return jax.tree.map(
            lambda r: jnp.zeros(r.shape[:ax] + (self.max_slots,) + r.shape[ax + 1 :], r.dtype),
            rows,
        )

    def admit(self, slots: List[int], stats_list, overrides=None):
        """Fuse stats -> per-slot rows (both tiers when ``draft_ratio`` is
        set), scatter them into the arena(s), and return the freshly built
        TARGET rows (slot axis length ``len(slots)``) so the engine can
        derive host-side keys (e.g. active-block lists for the shared-list
        kernel grouping) without re-reading the arena.

        ``overrides`` (optional, one entry per slot) carries a request's
        ``(density, draft_density)`` when it differs from the engine
        config — see :meth:`_override_fn` for how a lower density shares
        the fixed-capacity arena.  ``None`` entries take the engine-default
        (bit-identical to the pre-override build path).  The override path
        is single-slot (the paged engine finalizes one request per prefill
        chunk); batch admission with overrides would need per-slot row
        stacking to honor the return contract."""
        if overrides is not None and any(o is not None for o in overrides):
            assert len(overrides) == len(slots) == 1, "override admits are single-slot"
            (slot,), (st,), (ov,) = slots, stats_list, overrides
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[st])
            rows, drows = self._override_fn(*ov)(self.params, self.prior, stacked)
            idx = jnp.asarray([slot], jnp.int32)
            if self.arena is None:
                self.arena = self._init_arena(rows)
            self.arena = self._write(self.arena, rows, idx)
            if self.tiered:
                if self.draft_arena is None:
                    self.draft_arena = self._init_arena(drows)
                self.draft_arena = self._write(self.draft_arena, drows, idx)
            return rows
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stats_list)
        rows, drows = self._rows(self.params, self.prior, stacked)
        idx = jnp.asarray(slots, jnp.int32)
        if self.arena is None:
            self.arena = self._init_arena(rows)
        self.arena = self._write(self.arena, rows, idx)
        if self.tiered:
            if self.draft_arena is None:
                self.draft_arena = self._init_arena(drows)
            self.draft_arena = self._write(self.draft_arena, drows, idx)
        return rows

    def save(self, slot: int):
        """Device copy of the slot's rows, both tiers (swap-out keeps GLASS
        state)."""
        if self.arena is None:
            return None
        draft = self._save(self.draft_arena, jnp.int32(slot)) if self.tiered else None
        return (self._save(self.arena, jnp.int32(slot)), draft)

    def restore(self, slot: int, rows) -> None:
        """Write back rows captured by :meth:`save` at a (new) slot.  The
        arenas are lazily initialized from the rows' own shapes: a migrated
        request may land on an engine that has not admitted anything yet."""
        if rows is None:
            return
        target, draft = rows
        if self.arena is None:
            self.arena = self._init_arena(target)
        self.arena = self._write(self.arena, target, jnp.asarray([slot], jnp.int32))
        if draft is not None:
            if self.draft_arena is None:
                self.draft_arena = self._init_arena(draft)
            self.draft_arena = self._write(self.draft_arena, draft, jnp.asarray([slot], jnp.int32))

    def clear(self, slot: int) -> None:
        """Zero the slot's row in every tier's arena.  A zero mask / zero
        compact gather makes the FFN contribution of an inactive slot
        exactly zero — cheap hygiene on top of the engine never reading
        inactive slots' logits."""
        if self.arena is not None:
            self.arena = self._clear(self.arena, jnp.int32(slot))
        if self.draft_arena is not None:
            self.draft_arena = self._clear(self.draft_arena, jnp.int32(slot))


@dataclass
class MigrationTicket:
    """One request's complete host-side serving state in flight between two
    engines (cross-replica migration).

    Everything device-side travels in ``wire`` (KV blocks + recurrent-state
    rows, pool-independent — :class:`~repro.serve.kv_pool.SwappedWire`) and
    ``glass_rows`` (the GLASS slot rows, device_get to host numpy).
    Everything host-side is the request's lifecycle bookkeeping: the token
    stream, the forced-replay cursor, the counter-based PRNG position, and
    the resolved per-request policies — exactly the fields the destination
    needs to continue the stream bit-identically.  ``mid_prefill`` tickets
    carry ``pstats`` (the partial GLASS stat left-fold, host numpy) instead
    of ``glass_rows``: the mask is not finalized yet, so the destination
    resumes the chunked prefill at ``prefill_pos`` (always a chunk
    boundary — migration runs between ticks) and keeps folding.

    In-process this is a plain dataclass; a multi-process transport would
    serialize exactly these fields (the arrays are host numpy throughout).
    """

    req: Request
    sp: SamplingParams
    gp: GlassParams
    wire: SwappedWire
    outputs: List[int]
    pending: int
    replay_left: int
    rng_pos: int
    emitted: int
    preemptions: int
    prefill_pos: int
    mid_prefill: bool
    glass_rows: Any = None  # host copy of GlassSlotState.save(slot), or None
    glass_key: Optional[bytes] = None  # block_sparse decode grouping key
    pstats: Any = None  # host stat-sum snapshot (mid-prefill tickets only)


class _QueueEngineBase:
    """Shared host-side plumbing for the queue-driven engines: submission,
    first-token sampling, finish bookkeeping, and the drain loop.
    Subclasses provide ``step()`` (one tick group) and ``_drain_budget()``
    (a safe upper bound on ticks to drain the current workload), and may
    hook ``_on_free`` for extra per-slot teardown."""

    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    @property
    def n_active(self) -> int:
        return int(self.pool.active.sum())

    def _first_token(self, logits_last: np.ndarray) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits_last))
        self._rng, krng = jax.random.split(self._rng)
        return int(
            sample(krng, jnp.asarray(logits_last)[None], temperature=self.temperature,
                   top_k=self.top_k)[0]
        )

    def _on_free(self, slot: int) -> None:
        pass

    def _finish(self, slot: int, finished: List[FinishedRequest]) -> None:
        r = self.live[slot]
        finished.append(
            FinishedRequest(
                uid=r.uid,
                prompt=np.asarray(r.prompt, np.int32),
                tokens=np.asarray(self.outputs[slot], np.int32),
                arrival=r.arrival,
                admitted_step=self.admitted_step[slot],
                finished_step=self.t,
            )
        )
        self.pool.free(slot)
        if self.glass_slots is not None:
            self.glass_slots.clear(slot)
        self.live[slot] = None
        self.outputs[slot] = None
        self.pending[slot] = 0
        self._on_free(slot)

    def _inflight_requests(self) -> List[Request]:
        return [r for r in self.live if r is not None]

    def _work_remaining(self) -> bool:
        return bool(len(self.scheduler) or self.pool.active.any())

    def run(self, requests=(), max_steps: Optional[int] = None) -> Dict[int, FinishedRequest]:
        """Serve until queue and slots drain; returns {uid: finished output}
        (legacy ``FinishedRequest``, or the structurally-superset final
        ``RequestOutput`` from the streaming paged engine — streaming
        deltas are filtered out here)."""
        for r in requests:
            self.submit(r)  # the subclass's validation applies
        if max_steps is None:
            queued = list(self.scheduler.queue)
            live = self._inflight_requests()
            budget = self._drain_budget(queued, live)
            arrivals = [r.arrival for r in queued] + [0]
            max_steps = self.t + max(arrivals) + budget + len(queued) + self.pool.max_slots + 8
        done: Dict[int, FinishedRequest] = {}
        while self._work_remaining():
            if self.t > max_steps:
                raise RuntimeError(
                    f"{type(self).__name__} did not drain in {max_steps} steps"
                )
            for f in self.step():
                if getattr(f, "finished", True):
                    done[f.uid] = f
        return done


class ContinuousEngine(_QueueEngineBase):
    """Continuous-batching server: admit-as-slots-free, decode over a fixed
    arena, evict on completion.

    Greedy by default (``temperature=0``); with a temperature the sampled
    stream is deterministic given ``rng`` but not token-compatible with the
    static ``Engine`` (different rng consumption order).
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked
        temperature: float = 0.0,
        top_k: int = 0,
        rng: Optional[jax.Array] = None,
        decode_chunk: int = 8,  # max ticks fused into one jitted scan
    ):
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if model.cfg.is_encoder_decoder:
            raise NotImplementedError("continuous batching targets decoder LMs")
        self.model = model
        self.params = params
        self.temperature = temperature
        self.top_k = top_k
        self.pool = KVPool(model, max_slots, max_len)
        self.scheduler = Scheduler(max_len)
        self.glass_slots = (
            GlassSlotState(model, params, glass, global_prior, glass_mode, max_slots)
            if glass is not None
            else None
        )
        self.pending = np.zeros((max_slots,), np.int32)  # next token to feed, per slot
        self.outputs: List[Optional[List[int]]] = [None] * max_slots
        self.live: List[Optional[Request]] = [None] * max_slots
        self.admitted_step = [0] * max_slots
        self.t = 0  # engine step counter == decode ticks
        self.slot_steps = 0  # decode ticks x active slots (scheduling telemetry)
        self._rng = rng if rng is not None else jax.random.key(0)

        # prefill at the request's exact length (jit caches per length); the
        # cache is sized to the prompt so the pool insert stays minimal
        self._prefill = jax.jit(lambda pr, tk: model.prefill(pr, {"tokens": tk}, tk.shape[1]))

        mode = self.glass_slots.mode if self.glass_slots is not None else None
        # fused-decode horizon: whenever the scheduler can prove no admission
        # or eviction can happen for H ticks, H decode steps run as ONE jitted
        # scan — the host round-trip (the dominant per-token cost at small
        # scale) is paid once per chunk instead of once per token.  H is
        # bucketed to powers of two so at most log2(chunk)+1 variants compile.
        self.decode_chunk = max(1, decode_chunk)

        bsz = glass.block_size if glass is not None else 128

        def dec(pr, cache, lengths, toks, extra, rng, H):
            kw = {}
            if mode == "masked":
                kw["ffn_masks"] = extra
            elif mode == "compact":
                kw["compact_layers"] = extra
            elif mode == "block_sparse":
                kw["ffn_block_idx"] = extra["idx"]
                kw["ffn_block_scale"] = extra["scale"]
                kw["ffn_block_size"] = bsz

            def body(carry, _):
                cache, lengths, toks, rng = carry
                lg, cache = model.decode_step(pr, toks[:, None], cache, lengths, **kw)
                lg = lg[:, -1].astype(jnp.float32)
                rng, krng = jax.random.split(rng)
                if temperature > 0.0:
                    nxt = sample(krng, lg, temperature=temperature, top_k=top_k)
                else:
                    nxt = jnp.argmax(lg, axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (cache, lengths + 1, nxt, rng), nxt

            (cache, _, _, rng), seq = jax.lax.scan(
                body, (cache, lengths, toks, rng), None, length=H
            )
            return seq, cache, rng  # seq (H, B)

        # the arena is dead after each chunk — donate it so XLA updates the
        # KV cache in place instead of copying max_slots * max_len every tick
        self._decode = jax.jit(dec, static_argnums=(6,), donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def _horizon(self) -> int:
        """Largest safe fused-decode length: bounded by the first possible
        eviction (min remaining tokens of any active slot) and — when a free
        slot could accept it — the next queued arrival.  Bucketed to a power
        of two so the chunked decode compiles O(log chunk) variants."""
        active = np.nonzero(self.pool.active)[0]
        h = min(self.live[int(s)].max_new - len(self.outputs[int(s)]) for s in active)
        if self.pool.n_free and len(self.scheduler):
            na = self.scheduler.next_arrival()
            if na is not None:  # all remaining arrivals are in the future
                h = min(h, na - self.t)
        h = min(h, self.decode_chunk)
        p = 1
        while p * 2 <= h:
            p *= 2
        return p

    def step(self) -> List[FinishedRequest]:
        """One engine tick group: admit arrived requests into free slots
        (prefill interleaved with decode), then decode the largest provably
        safe chunk of tokens for every active slot.  Returns requests
        finished in this group."""
        finished: List[FinishedRequest] = []
        reqs = self.scheduler.pop_admissible(self.t, self.pool.n_free)
        if reqs:
            self._admit(reqs, finished)
        if self.pool.active.any():
            H = self._horizon()
            extra = self.glass_slots.arena if self.glass_slots is not None else None
            seq, cache, self._rng = self._decode(
                self.params,
                self.pool.cache,
                jnp.asarray(self.pool.lengths),
                jnp.asarray(self.pending),
                extra,
                self._rng,
                H,
            )
            self.pool.cache = cache
            seq = np.asarray(seq)  # (H, B)
            self.slot_steps += H * int(self.pool.active.sum())
            for s in np.nonzero(self.pool.active)[0]:
                s = int(s)
                self.pool.lengths[s] += H
                self.outputs[s].extend(int(x) for x in seq[:, s])
                self.pending[s] = seq[-1, s]
                if len(self.outputs[s]) >= self.live[s].max_new:
                    self._finish(s, finished)
            self.t += H
        else:
            na = self.scheduler.next_arrival()
            # idle: fast-forward to the next arrival instead of spinning
            self.t = max(self.t + 1, na if na is not None else self.t + 1)
        return finished

    def _drain_budget(self, queued: List[Request], live: List[Request]) -> int:
        return sum(r.max_new for r in queued) + sum(r.max_new for r in live)

    # -- internals ----------------------------------------------------------

    def _admit(self, reqs: List[Request], finished: List[FinishedRequest]) -> None:
        slots, stats_list = [], []
        for r in reqs:
            slot = self.pool.alloc()
            toks = jnp.asarray(np.asarray(r.prompt, np.int32))[None]
            logits, cache, stats = self._prefill(self.params, toks)
            first = self._first_token(np.asarray(logits[0, -1], np.float32))
            self.pool.write_prefill(slot, cache, len(r.prompt))
            self.pending[slot] = first
            self.outputs[slot] = [first]
            self.live[slot] = r
            self.admitted_step[slot] = self.t
            slots.append(slot)
            stats_list.append(stats)
        if self.glass_slots is not None:
            self.glass_slots.admit(slots, stats_list)
        for slot in slots:  # max_new == 1 completes without a decode tick
            if len(self.outputs[slot]) >= self.live[slot].max_new:
                self._finish(slot, finished)


# ---------------------------------------------------------------------------
# Paged continuous batching (block table + chunked prefill)
# ---------------------------------------------------------------------------


class PagedEngine(_QueueEngineBase):
    """Continuous batching over a paged KV block table, driven by an
    explicit per-request lifecycle state machine (``serve.lifecycle``).

    Differences vs :class:`ContinuousEngine` (which is kept as the
    slot-arena reference — both are greedy-token-identical to single-request
    serving):

      * **memory** — a :class:`BlockPool` with *allocate-on-boundary*
        (``alloc_mode="incremental"``, the default): admission allocates
        only the first prefill chunk's blocks, and a request grows one
        block at a time as it crosses block boundaries, with a small
        watermark reserve kept free for growth.  ``alloc_mode="full"``
        restores the PR-2 behavior (the request's entire worst-case
        footprint reserved at admission) for comparison.
      * **preemption** — when growth fails under pressure, the scheduler
        picks a victim (lowest priority / latest deadline / newest first)
        and a cost model picks *swap* (KV blocks copied to a host store,
        restored bit-identical on swap-in) or *recompute* (blocks dropped,
        request re-queued; the prompt replays through chunked prefill —
        running-sum GLASS stats rebuild the identical fused mask — and the
        generated prefix re-feeds through decode as forced tokens).  Both
        paths resume with zero token-stream divergence for greedy AND
        seeded-sampled requests: sampling is counter-based (every draw is
        a pure function of (request seed, generated position, logits) —
        see ``serve.sampling.sample_positional``), so replay regenerates
        the stream bit-identically and no engine-global RNG state exists
        to shift.
      * **prefill** — prompts are processed in chunks of at most
        ``chunk_tokens`` per engine tick, interleaved with decode; the
        fused mask is built once, at the final chunk.
      * **decode** — one jitted step over the fixed ``max_slots`` decode
        batch reading through the block table, gather width bucketed to
        the longest active request.  In ``block_sparse`` mode, rows whose
        active-block lists coincide are batched through the shared-list
        ``glass_ffn`` kernel (group-by on the host-side block-id tuples);
        singleton rows fall back to ``glass_ffn_rowwise``.
      * **admission** — ``AdmissionPolicy`` (FIFO / priority / deadline),
        best-effort under block availability net of the watermark reserve
        and the blocks owed to swapped-out requests awaiting swap-in.
      * **speculative decode** (``spec_k > 0``, per request) — the same
        weights under a more aggressive GLASS tier
        (``GlassConfig(draft_ratio=...)``, per-slot tiered masks built once
        at prefill finalize) draft ``k`` tokens per round in one fused
        scan; the target tier verifies all ``k + 1`` positions through the
        forced-token (ftoks/fmask) scan — the pre-override verdict at each
        step (argmax, or the positional sample for seeded requests) IS the
        target verdict — and the longest matching prefix plus one bonus
        token is accepted.  Rejected rows are un-scattered, speculative
        block growth is released in reverse order, and recurrent-state
        carries are replayed from the pre-draft checkpoint, so the pool is
        BIT-identical to never having speculated (the state-invariant
        suite in ``tests/test_speculative_decode.py`` enforces exactly
        that, including through mid-speculation preemption).  Requests
        with ``GlassParams(spec_k=0)`` interleave with speculating ones in
        the same tick via a plain decode over the non-participants.
      * **attention path** (``attn_mode``) — ``"gather"`` materializes the
        logical KV view through the block table before a reference
        attention (the fallback and correctness oracle);
        ``"paged_pallas"`` runs the fused paged-attention kernel
        (``kernels/paged_attention.py``): block-table indirection,
        causal/window masking, and online softmax in one pass, streaming
        only live blocks.  Greedy token streams are identical either way.
      * **speculative verify** (``verify_mode``) — ``"sequential"`` walks
        the ``k + 1`` verify positions through the unrolled decode scan;
        ``"parallel"`` scores all positions in ONE ``T``-wide forward
        (``Model.verify_steps``), bit-identical on every live KV row by
        construction (every KV-writing program is inline-compiled, never
        a ``lax.scan`` body — see the comment in the decode builder).
        ``"auto"`` picks parallel exactly when the family is stateless
        and ``attn_mode="paged_pallas"``.

    **Per-request generation API** (the streaming frontend): submit with
    :meth:`add_request` under request-scoped :class:`SamplingParams`
    (counter-based seeded sampling, EOS/stop sets detected inside the
    fused scan) and :class:`GlassParams` (density / draft_ratio / spec_k
    against the engine's capacity tier); consume
    :class:`~repro.serve.scheduler.RequestOutput` deltas from every
    :meth:`step`; cancel with :meth:`abort`.  The legacy
    ``submit(Request)`` / ``run(requests)`` pair keeps working (greedy at
    engine defaults) behind a DeprecationWarning.

    ``PagedEngine.step`` itself is a thin driver: each tick it asks the
    lifecycle for this tick's swap-in, admission, prefill, and decode
    work, in that order; all resource movement happens inside the state
    transitions.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_slots: int = 8,
        max_len: int = 256,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        chunk_tokens: int = 32,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked | block_sparse
        policy: AdmissionPolicy = AdmissionPolicy.FIFO,
        alloc_mode: str = "incremental",  # incremental | full
        preemption: Optional[PreemptionConfig] = None,
        spec_k: int = 0,  # default draft tokens per speculative round (0 = off)
        temperature: float = 0.0,  # legacy engine-global default (see sampling)
        top_k: int = 0,
        rng: Optional[jax.Array] = None,  # unused: sampling is counter-based
        decode_chunk: int = 8,  # max ticks fused into one jitted scan
        sampling: Optional[SamplingParams] = None,  # default SamplingParams
        prefix_cache: bool = False,  # content-addressed KV prefix reuse
        attn_mode: str = "gather",  # gather | paged_pallas (fused kernel)
        verify_mode: str = "auto",  # auto | sequential | parallel spec verify
    ):
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"
        if model.cfg.is_encoder_decoder:
            raise NotImplementedError("continuous batching targets decoder LMs")
        if attn_mode not in ("gather", "paged_pallas"):
            raise ValueError(f"unknown attn_mode {attn_mode!r}")
        if verify_mode not in ("auto", "sequential", "parallel"):
            raise ValueError(f"unknown verify_mode {verify_mode!r}")
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
        if alloc_mode not in ("incremental", "full"):
            raise ValueError(f"unknown alloc_mode {alloc_mode!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k and (glass is None or glass.draft_ratio is None):
            raise ValueError(
                "speculative decode needs GlassConfig(draft_ratio=...) — "
                "the draft model IS the same weights under the draft tier"
            )
        self.model = model
        self.params = params
        self.temperature = temperature
        self.top_k = top_k
        # the default per-request sampling policy: requests submitted without
        # SamplingParams inherit it.  The legacy engine-global
        # (temperature, top_k) pair maps onto it — with a temperature, each
        # request gets a stable uid-derived seed, so the "global" setting is
        # served by per-request counter-based streams (reproducible through
        # preemption/replay, unlike the old shared RNG stream).
        if sampling is not None:
            self.default_sampling = sampling
        elif temperature <= 0.0:
            self.default_sampling = SamplingParams.make_greedy()
        else:
            self.default_sampling = None  # per-uid seed derived at submit
        if rng is not None:
            warnings.warn(
                "PagedEngine(rng=...) is ignored: sampling is counter-based "
                "per request — pass SamplingParams(seed=...) (per request or "
                "as the engine `sampling` default) to vary streams",
                DeprecationWarning, stacklevel=2,
            )
        self._auto_uid = itertools.count()
        self._used_uids: set = set()  # every uid ever submitted (auto-uid guard)
        # per-uid (SamplingParams, GlassParams) resolved at submit; consumed
        # at admission, dropped at finish/abort
        self._policies: Dict[int, Tuple[SamplingParams, GlassParams]] = {}
        self.chunk_tokens = chunk_tokens
        self.alloc_mode = alloc_mode
        self.preempt_cfg = preemption if preemption is not None else PreemptionConfig()
        watermark = self.preempt_cfg.watermark_blocks if alloc_mode == "incremental" else 0
        # the cache namespace folds the model config (and the GLASS config,
        # which shapes the stat snapshots) into every chain key: prefix
        # chains are content-addressed by (token ids, model config)
        self.pool = BlockPool(model, max_slots, max_len, block_size, num_blocks,
                              watermark=watermark, prefix_cache=prefix_cache,
                              cache_namespace=repr((model.cfg, glass)))
        self.scheduler = Scheduler(max_len, policy=policy)
        self.glass = glass
        self.glass_slots = (
            GlassSlotState(model, params, glass, global_prior, glass_mode, max_slots)
            if glass is not None
            else None
        )
        self.lc = Lifecycle()
        self.t = 0
        self.slot_steps = 0  # decode ticks x decoding slots (scheduling telemetry)
        self.kv_row_ticks = 0  # allocated KV rows x ticks (memory telemetry)
        self.max_prefill_tokens_per_tick = 0
        # preemption / admission telemetry
        self.swap_bytes = 0  # bytes copied device -> host by swap-outs
        self.swap_ins = 0
        self.recompute_tokens = 0  # tokens dropped by recompute preemptions
        # host swap-store residency (PreemptionConfig.swap_store_cap_bytes)
        self.swap_store_bytes = 0  # resident host bytes across swapped entries
        self.swap_cap_evictions = 0  # swapped requests degraded to recompute
        self._swap_seq = itertools.count()  # swap-out order (cap evicts oldest)
        # cross-engine migration telemetry (driven by serve.cluster)
        self.migrations_out = 0
        self.migrations_in = 0
        self.migration_bytes = 0  # wire bytes exported by migrate_out
        self.grouped_rows = 0  # decode row-ticks served by the shared-list kernel
        self.admission_waits: List[int] = []  # first-admission latency per request
        self.decode_chunk = max(1, decode_chunk)
        # speculative-decode knob + telemetry
        self.spec_k = spec_k
        self.spec_ticks = 0  # speculative rounds run
        self.spec_slot_ticks = 0  # speculative rounds x participating slots
        self.spec_drafted = 0  # draft tokens proposed
        self.spec_accepted = 0  # draft tokens accepted by the target tier
        self.spec_emitted = 0  # tokens emitted by speculative rounds (accepted + bonus)
        self.spec_rollbacks = 0  # per-slot rounds that rejected >= 1 draft token
        self.spec_rolled_back_rows = 0  # KV rows un-scattered by rollbacks

        mode = self.glass_slots.mode if self.glass_slots is not None else None
        self._mode = mode
        bsz = glass.block_size if glass is not None else 128
        has_paged = self.pool.has_paged
        axes_t, paged_t = self.pool.axes, self.pool.paged
        has_state = not all(jax.tree.leaves(self.pool.paged))
        if attn_mode == "paged_pallas" and not has_paged:
            raise ValueError(
                "attn_mode='paged_pallas' needs a paged KV cache — this "
                "family has no attention block table to fuse over"
            )
        self.attn_mode = attn_mode
        if verify_mode == "parallel" and has_state:
            raise ValueError(
                "verify_mode='parallel' targets attention-backed families; "
                "recurrent state must advance token-by-token to stay "
                "bit-identical to sequential decode"
            )
        # auto: the fused kernel's query-on-grid construction is what makes
        # a T = k+1 verify forward bitwise equal to k+1 sequential ticks, so
        # the one-forward verify rides with attn_mode="paged_pallas" on
        # stateless families and stays sequential otherwise
        self._verify_parallel = verify_mode == "parallel" or (
            verify_mode == "auto" and not has_state and attn_mode == "paged_pallas"
        )
        self.verify_mode = verify_mode
        self.programs = ProgramCache()

        # the fused horizon H is carried by the (H, B) leading axis of
        # ftoks/fmask — the scan length and the per-H jit variants key off
        # that shape, so no separate static argument is needed.  All
        # per-request policy rides in traced (B,) vectors: pos0 (the
        # counter-based PRNG position of each slot's first emission this
        # scan), seeds/temp/topk/gmask (SamplingParams), and stop_ids
        # (the per-slot early-finish stop set, -1 padded).  ``sampled``
        # is the only policy static: an all-greedy batch compiles without
        # any sampling ops, preserving the PR-4 greedy program exactly.
        def mk_kw(extra, btab, perm, groups):
            kw = {}
            if mode == "masked":
                kw["ffn_masks"] = extra
            elif mode == "compact":
                kw["compact_layers"] = extra
            elif mode == "block_sparse":
                kw["ffn_block_idx"] = extra["idx"]
                kw["ffn_block_scale"] = extra["scale"]
                kw["ffn_block_size"] = bsz
                if groups:  # shared-list batching: rows with identical lists
                    kw["ffn_groups"] = groups
                    kw["ffn_row_perm"] = perm
            if has_paged:
                kw["block_table"] = btab
                kw["attn_mode"] = attn_mode
            return kw

        def dec(pr, arena, lengths, toks, btab, dmask, extra, ftoks, fmask,
                perm, pos0, seeds, temp, topk, topp, minp, gmask, stop_ids,
                groups, sampled):
            kw = mk_kw(extra, btab, perm, groups)

            def guard(old, new, ax, pg):
                # recurrent-state rows of non-decoding slots (free, or holding
                # a mid-prefill request whose state IS the live prefill carry)
                # must not absorb the dummy-token recurrence; paged KV writes
                # are already scoped to live blocks by the trash-block table
                if pg:
                    return new
                m = dmask.reshape((1,) * ax + (-1,) + (1,) * (old.ndim - ax - 1))
                return jnp.where(m, new, old)

            def body(carry, xs):
                ft, fm = xs
                arena, lengths, pos, toks = carry
                lg, new = model.decode_step(pr, toks[:, None], arena, lengths, **kw)
                arena = jax.tree.map(guard, arena, new, axes_t, paged_t) if has_state else new
                lg = lg[:, -1].astype(jnp.float32)
                # the pre-override verdict: what the model WOULD emit at this
                # position — greedy argmax, or (for seeded slots) the
                # counter-based positional sample, a pure function of
                # (seed, position, logits).  Under forced re-feeds this is
                # exactly the target-tier verdict the speculative verify
                # pass accepts draft tokens against — greedy and sampled
                # requests alike.
                greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                if sampled:
                    samp = sample_positional(
                        lg, seeds, pos, temp, topk, top_p=topp, min_p=minp
                    )
                    verdict = jnp.where(gmask, greedy, samp)
                else:
                    verdict = greedy
                # recompute replay / speculative verify: re-feed the recorded
                # token instead of the fresh verdict — KV rebuilds
                # bit-identical (the positional draw would regenerate the
                # same token anyway; the override makes it structural)
                nxt = jnp.where(fm, ft, verdict)
                # early-finish detection inside the scan: the emitted token
                # against the slot's stop set (eos + stop ids, -1 padded);
                # forced re-feeds never re-trigger a stop
                hit = jnp.any(nxt[:, None] == stop_ids, axis=-1) & ~fm
                return (arena, lengths + 1, pos + 1, nxt), (nxt, verdict, hit)

            # UNROLLED, not lax.scan: XLA compiles a while-loop body with
            # different fusion choices than the same ops inlined, and the
            # two disagree at the last ulp deep in the layer stack.  Every
            # KV-writing program (this scan, the T-wide parallel verify, the
            # chunked prefill) must be inline-compiled so their stored rows
            # are bit-identical across programs — that is the invariant the
            # speculative state suite asserts.  H is pow2-bucketed by the
            # callers, so the unroll cost is bounded by the horizon buckets.
            carry = (arena, lengths, pos0, toks)
            outs = []
            for j in range(ftoks.shape[0]):
                carry, y = body(carry, (ftoks[j], fmask[j]))
                outs.append(y)
            arena = carry[0]
            seq, tgt, hits = (jnp.stack(z) for z in zip(*outs))
            return seq, tgt, hits, arena  # seq/tgt/hits (H, B)

        # the arena is dead after each call — donate so the block pool (and
        # state rows) update in place instead of copying every tick
        self._decode = self.programs.register(
            "decode", dec, static_argnums=(18, 19), donate_argnums=(1,)
        )

        # the parallel speculative verify: every feed of a verify round is
        # already known (pending + the k drafts, all forced), so stateless
        # families answer all k+1 positions with ONE T-wide forward instead
        # of a k+1-step scan.  The verdict math per position is byte-for-byte
        # the scan body's; the fused attention kernel runs each query as its
        # own grid program, so logits — and therefore verdicts and the KV
        # rows the round scatters — are BIT-identical to the sequential path
        # (the speculative state-invariant suite asserts it).
        def pver(pr, arena, lengths, feed, btab, extra, perm, pos0, seeds,
                 temp, topk, topp, minp, gmask, groups, sampled):
            kw = mk_kw(extra, btab, perm, groups)
            lg, arena = model.decode_step(pr, feed, arena, lengths, **kw)
            lg = lg.astype(jnp.float32)  # (B, T, V)
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            if sampled:
                Bf, Tf = feed.shape
                rep = lambda a: jnp.repeat(a, Tf, axis=0)
                pos = (
                    pos0[:, None] + jnp.arange(Tf, dtype=jnp.int32)[None]
                ).reshape(-1)
                samp = sample_positional(
                    lg.reshape(Bf * Tf, -1), rep(seeds), pos, rep(temp),
                    rep(topk), top_p=rep(topp), min_p=rep(minp),
                ).reshape(Bf, Tf)
                verdict = jnp.where(gmask[:, None], greedy, samp)
            else:
                verdict = greedy
            return verdict.swapaxes(0, 1), arena  # verdicts (k+1, B)

        self._pverify = self.programs.register(
            "verify_parallel", pver, static_argnums=(14, 15), donate_argnums=(1,)
        )

        axes, paged = self.pool.axes, self.pool.paged

        def chunk(pr, arena, toks, clen, btab, slot):
            # state leaves: slice this slot's rows out of the arena; paged
            # leaves pass through whole (the block table scopes the access)
            def take(a, ax, pg):
                return a if pg else jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            rows = jax.tree.map(take, arena, axes, paged)
            ckw = {"attn_mode": attn_mode} if has_paged else {}
            logits, new, stats = model.prefill_chunk(
                pr, toks, rows, clen,
                block_table=btab if has_paged else None, **ckw,
            )

            def put(a, n, ax, pg):
                if pg:
                    return n
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, n.astype(a.dtype), starts)

            arena = jax.tree.map(put, arena, new, axes, paged)
            return logits[:, -1], arena, stats

        self._chunk = self.programs.register("chunk", chunk, donate_argnums=(1,))

    # -- public API ---------------------------------------------------------

    def add_request(
        self,
        prompt,
        max_new: int,
        *,
        sampling: Optional[SamplingParams] = None,
        glass: Optional[GlassParams] = None,
        uid: Optional[int] = None,
        arrival: Optional[int] = None,
        priority: int = 0,
        deadline: Optional[int] = None,
    ) -> int:
        """The streaming frontend entry: enqueue one request under its own
        :class:`SamplingParams` (temperature / top-k / seed / stop set —
        ``None`` inherits the engine default, greedy unless configured) and
        :class:`GlassParams` (density / draft_ratio / spec_k — ``None``
        fields inherit the engine :class:`GlassConfig`).  Returns the
        request's uid (auto-assigned when not given).

        Consume results incrementally: every :meth:`step` returns
        :class:`RequestOutput` deltas for live requests (``new_tokens``)
        and a final ``finished=True`` output with a ``finish_reason``
        (``length | stop | eos | aborted``); :meth:`abort` cancels a
        request in any state, releasing its blocks/slot/GLASS rows through
        the lifecycle."""
        if uid is None:
            # _used_uids covers FINISHED requests too (Lifecycle prunes
            # their entries): an auto uid must never alias an earlier
            # request in a uid-keyed consumer's results, even a drained one
            uid = next(self._auto_uid)
            while uid in self._used_uids:  # covers queued + in-flight too
                uid = next(self._auto_uid)
        req = Request(
            uid=uid, prompt=np.asarray(prompt, np.int32), max_new=max_new,
            arrival=self.t if arrival is None else arrival,
            priority=priority, deadline=deadline,
            sampling=sampling, glass=glass,
        )
        self._submit(req)
        return uid

    def submit(self, req: Request) -> None:
        """Legacy frontend: a bare :class:`Request` decodes greedy (or the
        engine-global temperature) at the engine's GLASS config.  Kept as a
        deprecation shim over :meth:`add_request`."""
        warnings.warn(
            "PagedEngine.submit(Request) / run(requests) are the legacy "
            "frontend; use add_request(...) with SamplingParams/GlassParams "
            "and consume RequestOutput deltas from step()",
            DeprecationWarning, stacklevel=2,
        )
        self._submit(req)

    def _submit(self, req: Request) -> None:
        need = self.pool.blocks_needed(self._rows_needed(req))
        if self.pool.has_paged and need > self.pool.num_blocks - 1:
            raise ValueError(
                f"request {req.uid} needs {need} blocks > pool capacity "
                f"{self.pool.num_blocks - 1}"
            )
        # uids key the lifecycle entries, so a resubmission while the first
        # request is still queued or in flight must fail HERE, not crash at
        # admission (entries exist only from admission on, hence both checks)
        if req.uid in self.lc.entries or any(q.uid == req.uid for q in self.scheduler.queue):
            raise ValueError(f"request uid {req.uid} is already in flight")
        # resolve + validate per-request policy WITHOUT mutating the
        # caller's Request (the same object may be re-served through a
        # differently-configured engine); the admission tick binds the
        # resolved pair onto the LiveRequest entry
        self._policies[req.uid] = self._resolve_policy(req)
        self._used_uids.add(req.uid)
        _QueueEngineBase.submit(self, req)

    def _resolve_policy(self, req: Request) -> Tuple[SamplingParams, GlassParams]:
        """Resolve + validate the request's per-request policy against the
        engine defaults (the engine GlassConfig is the *capacity* tier)."""
        sp = req.sampling
        if sp is None:
            if self.default_sampling is not None:
                sp = self.default_sampling
            else:
                # legacy engine-global temperature: a stable uid-derived seed
                # keeps the stream reproducible through preemption/replay
                sp = SamplingParams(
                    temperature=self.temperature, top_k=self.top_k,
                    seed=(req.uid * 2654435761 + 97) % (2**31 - 1),
                )
        gp = (req.glass if req.glass is not None else GlassParams()).resolve(
            self.glass, self.spec_k
        )
        if self.glass is None:
            if gp.density is not None or gp.draft_ratio is not None:
                raise ValueError(
                    f"request {req.uid}: per-request GLASS params need an "
                    "engine-level GlassConfig (the engine serves dense)"
                )
            if gp.spec_k:
                raise ValueError(
                    f"request {req.uid}: spec_k > 0 needs an engine "
                    "GlassConfig(draft_ratio=...) draft tier"
                )
            return sp, gp
        eps = 1e-9
        if gp.density > self.glass.density + eps:
            raise ValueError(
                f"request {req.uid}: density {gp.density} exceeds the engine "
                f"capacity tier {self.glass.density} (per-request selections "
                "must nest inside the engine config's)"
            )
        if (req.glass is not None and req.glass.draft_ratio is not None
                and self.glass.draft_ratio is None):
            # consistent with density: a per-request knob the engine cannot
            # honor must raise, not silently do nothing
            raise ValueError(
                f"request {req.uid}: draft_ratio needs an engine "
                "GlassConfig(draft_ratio=...) draft arena"
            )
        if gp.spec_k:
            if self.glass.draft_ratio is None or gp.draft_ratio is None:
                raise ValueError(
                    f"request {req.uid}: spec_k > 0 needs an engine "
                    "GlassConfig(draft_ratio=...) draft tier"
                )
            if (gp.density * gp.draft_ratio
                    > self.glass.density * self.glass.draft_ratio + eps):
                raise ValueError(
                    f"request {req.uid}: draft density "
                    f"{gp.density * gp.draft_ratio} exceeds the engine draft "
                    f"capacity {self.glass.density * self.glass.draft_ratio}"
                )
        return sp, gp

    def abort(self, uid: int) -> Optional[RequestOutput]:
        """Cancel a request in any state, releasing every resource it holds
        through the lifecycle: a queued request is removed, a PREFILLING /
        RUNNING one frees its slot + blocks + GLASS rows, a SPECULATING one
        first rolls back its pending drafts (the only legal exit), a
        swapped one drops its host store, and a recompute-queued one is
        de-queued.  Returns the final aborted :class:`RequestOutput` (with
        whatever tokens were accepted so far), or None if the uid is not
        live."""
        e = self.lc.entries.get(uid)
        if e is None:
            r = self.scheduler.remove(uid)
            if r is None:
                return None
            e = self.lc.add(r)
            self.lc.to(e, ReqState.FINISHED)
            self._policies.pop(uid, None)
            e.finish_reason = "aborted"
            return self._output(e, finished=True, reason="aborted")
        if e.state is ReqState.FINISHED:
            return None
        if e.state is ReqState.SPECULATING:
            self._rollback_speculation(e)
        if e.state in (ReqState.PREFILLING, ReqState.RUNNING):
            self.pool.free(e.slot)
            if self.glass_slots is not None:
                self.glass_slots.clear(e.slot)
            e.slot = -1
            e.pstats = None
        elif e.state is ReqState.PREEMPTED_SWAPPED:
            # a swapped request keeps ownership refs on shared prefix
            # blocks it never copied to host — drop them or they leak
            self.pool.release_swapped(e.swap)
            self.swap_store_bytes -= e.swap.nbytes
            e.swap = None
            e.swap_seq = -1
            e.glass_rows = None
        elif e.state is ReqState.MIGRATING:
            # abort-while-migrating: a migration store is a FULL swap (no
            # kept refs on either pool) and was never charged to this
            # engine's host store — dropping it releases both sides
            e.swap = None
            e.glass_rows = None
            e.pstats = None
        elif e.state is ReqState.PREEMPTED_RECOMPUTE:
            self.scheduler.remove(uid)
        self.lc.to(e, ReqState.FINISHED)
        self._policies.pop(uid, None)
        e.finish_reason = "aborted"
        return self._output(e, finished=True, reason="aborted")

    # -- cross-engine migration (replica-sharded serving) --------------------

    def migrate_out(self, uid: int) -> MigrationTicket:
        """Detach a live request into a :class:`MigrationTicket` another
        engine can adopt (:meth:`migrate_in`), leaving nothing of it here.

        A SPECULATING victim rolls back to its last accepted token first
        (the only legal exit).  RUNNING requests carry their GLASS slot
        rows; PREFILLING ones are handed off at the current chunk boundary
        with the partial stat left-fold instead (migration runs between
        ticks, so ``prefill_pos`` is always chunk-aligned).  An already
        PREEMPTED_SWAPPED request migrates only when its store is fully
        private — a store with ``kept`` shared blocks pins physical ids in
        THIS pool and raises.

        The device state leaves via a FULL swap-out: shared prefix blocks
        are copied out like private ones (their ids mean nothing in the
        destination pool) and this request's references released — the
        source's prefix cache keeps serving other requests unaffected."""
        e = self.lc.entries.get(uid)
        if e is None:
            raise KeyError(f"request {uid} is not live on this engine")
        if e.state is ReqState.SPECULATING:
            self._rollback_speculation(e)
        mid_prefill = e.state is ReqState.PREFILLING
        glass_rows = None
        pstats = None
        if e.state in (ReqState.RUNNING, ReqState.PREFILLING):
            slot = e.slot
            if mid_prefill:
                pstats = jax.device_get(snapshot_stat_sums(e.pstats))
            elif self.glass_slots is not None:
                glass_rows = jax.device_get(self.glass_slots.save(slot))
            if self.glass_slots is not None:
                self.glass_slots.clear(slot)
            e.preemptions += 1
            sw = self.pool.swap_out(slot, full=True)
            self.swap_bytes += sw.nbytes
            self.lc.to(e, ReqState.PREEMPTED_SWAPPED)
            e.slot = -1
        elif e.state is ReqState.PREEMPTED_SWAPPED:
            mid_prefill = e.prefill_pos < len(e.req.prompt)
            sw = e.swap
            glass_rows = jax.device_get(e.glass_rows) if e.glass_rows is not None else None
            pstats = jax.device_get(snapshot_stat_sums(e.pstats)) if mid_prefill else None
            self.swap_store_bytes -= sw.nbytes
            e.swap_seq = -1
        else:
            raise ValueError(
                f"request {uid} is {e.state.value} — only RUNNING / "
                "SPECULATING / PREFILLING / PREEMPTED_SWAPPED requests migrate"
            )
        wire = self.pool.export_swap(sw)  # raises on kept (non-portable) stores
        self.lc.to(e, ReqState.MIGRATING)
        self.lc.detach(e)
        self._policies.pop(uid, None)
        e.swap = None
        e.glass_rows = None
        e.pstats = None
        self.migrations_out += 1
        self.migration_bytes += wire.nbytes
        return MigrationTicket(
            req=e.req, sp=e.sp, gp=e.gp, wire=wire,
            outputs=list(e.outputs), pending=e.pending,
            replay_left=e.replay_left, rng_pos=e.rng_pos, emitted=e.emitted,
            preemptions=e.preemptions, prefill_pos=e.prefill_pos,
            mid_prefill=mid_prefill, glass_rows=glass_rows,
            glass_key=e.glass_key, pstats=pstats,
        )

    def migrate_in(self, ticket: MigrationTicket) -> None:
        """Adopt a migrated request: rebuild its swap store against this
        pool (cross-pool splice) and install a MIGRATING entry.  The next
        :meth:`step`'s swap-in tick — where migrated requests compete in
        the same policy order as ordinary swap-ins, with the same first
        claim on capacity — splices the blocks and resumes RUNNING (decode)
        or PREFILLING (mid-prefill handoff)."""
        r = ticket.req
        e = LiveRequest(req=r)
        e.state = ReqState.MIGRATING
        e.sp, e.gp = ticket.sp, ticket.gp
        e.outputs = list(ticket.outputs)
        e.pending = ticket.pending
        e.replay_left = ticket.replay_left
        e.rng_pos = ticket.rng_pos
        e.emitted = ticket.emitted
        e.preemptions = ticket.preemptions
        e.prefill_pos = ticket.prefill_pos
        e.cached_rows = 0  # no shared blocks survive a cross-pool move
        e.glass_key = ticket.glass_key
        e.swap = self.pool.adopt_wire(ticket.wire)
        e.glass_rows = ticket.glass_rows
        e.pstats = restore_stat_sums(ticket.pstats) if ticket.mid_prefill else None
        # admission-latency telemetry stays with the source engine: the
        # request was already admitted once, so the destination records
        # neither a wait nor a first admission
        e.admitted_step = self.t
        e.first_admitted_step = 0
        self.lc.adopt(e)
        self._policies[r.uid] = (e.sp, e.gp)
        self._used_uids.add(r.uid)
        self.migrations_in += 1

    # -- cluster admission inputs -------------------------------------------

    @property
    def pending_tokens(self) -> int:
        """Outstanding work in token units: un-prefilled prompt rows plus
        un-generated tokens, across the engine queue and every live entry.
        The cluster dispatcher's load estimate — token counts (not request
        counts) because GLASS per-request density/draft knobs make requests
        heterogeneous in cost."""
        w = 0
        for r in self.scheduler.queue:
            w += len(r.prompt) + r.max_new
        for e in self.lc.entries.values():
            if e.state is ReqState.FINISHED:
                continue
            done = len(e.outputs) - (e.spec_len if e.state is ReqState.SPECULATING else 0)
            w += max(0, len(e.req.prompt) - e.prefill_pos)
            w += max(0, e.req.max_new - done)
        return w

    def admission_cost_inputs(self, prompt=None) -> Dict[str, int]:
        """The per-replica signals the cluster dispatcher scores admissions
        with: free blocks net of the watermark reserve and the blocks owed
        to swapped/migrating requests, queue depth, outstanding token work,
        and (when ``prompt`` is given) the prefix-cache affinity probe —
        via the side-effect-free :meth:`BlockPool.peek_prefix`, so probing
        N replicas neither reorders any LRU nor skews hit-rate stats."""
        reserved = sum(
            e.swap.n_blocks
            for e in self.lc.in_state(ReqState.PREEMPTED_SWAPPED, ReqState.MIGRATING)
        )
        free = max(0, self.pool.n_available_blocks - self.pool.watermark - reserved)
        return dict(
            free_blocks=free,
            free_slots=self.pool.n_free_slots,
            queue_depth=len(self.scheduler),
            n_active=self.n_active,
            pending_tokens=self.pending_tokens,
            prefix_hit=(
                self.pool.peek_prefix(prompt, self.chunk_tokens)
                if prompt is not None else 0
            ),
        )

    @property
    def preempt_count(self) -> int:
        return self.lc.preempted()

    def _drain_budget(self, queued: List[Request], live: List[Request]) -> int:
        chunks = self.chunk_tokens
        base = sum(r.max_new + -(-len(r.prompt) // chunks) for r in queued + live)
        # preemption headroom: every swap/recompute round re-pays prefill
        # chunks and forced re-feeds; progress is still guaranteed (the
        # non-victim advances every tick) so a small multiple suffices
        return base * 4 + 16

    def _inflight_requests(self) -> List[Request]:
        return [
            e.req
            for e in self.lc.in_state(
                ReqState.PREFILLING, ReqState.RUNNING, ReqState.SPECULATING,
                ReqState.PREEMPTED_SWAPPED, ReqState.PREEMPTED_RECOMPUTE,
                ReqState.MIGRATING,
            )
        ]

    def _work_remaining(self) -> bool:
        return bool(
            len(self.scheduler)
            or self.pool.active.any()
            or self.lc.in_state(ReqState.PREEMPTED_SWAPPED, ReqState.MIGRATING)
        )

    def _rows_needed(self, r: Request) -> int:
        return len(r.prompt) + r.max_new - 1

    def _first_rows(self, r: Request) -> int:
        """Rows to allocate at admission: the first prefill chunk under
        incremental allocation, the full worst case under ``full``."""
        if self.alloc_mode == "full":
            return self._rows_needed(r)
        return min(self.chunk_tokens, len(r.prompt))

    def _fits(self, r: Request) -> bool:
        """Admission filter (satellite fix): under incremental allocation a
        request fits when its *first-chunk* blocks fit net of the watermark
        reserve and the blocks owed to swapped-out requests awaiting
        swap-in — not its full static need, which over-rejects, but also
        not raw free blocks, which would over-commit the pool."""
        if not self.pool.has_paged:
            return True
        if self.alloc_mode == "full":
            return self.pool.fits(self._rows_needed(r))
        reserved = sum(
            e.swap.n_blocks
            for e in self.lc.in_state(ReqState.PREEMPTED_SWAPPED, ReqState.MIGRATING)
        )
        return self.pool.fits_admission(self._first_rows(r), reserved)

    # -- per-request policy plumbing ----------------------------------------

    def _first_token_for(self, e: LiveRequest, logits_last: np.ndarray) -> int:
        """First post-prefill token under the request's own SamplingParams:
        greedy argmax, or the counter-based positional draw at position 0.
        Sampled exactly once per request — resume paths re-feed the
        recorded token instead of redrawing."""
        sp = e.sp
        if sp.is_greedy:
            return int(np.argmax(logits_last))
        return int(sample_positional(
            jnp.asarray(logits_last, jnp.float32)[None],
            jnp.asarray([np.int32(np.uint32(sp.seed))]),
            jnp.asarray([0], jnp.int32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            top_p=jnp.asarray([sp.top_p], jnp.float32),
            min_p=jnp.asarray([sp.min_p], jnp.float32),
        )[0])

    def _glass_override(self, e: LiveRequest):
        """The (density, draft_density) pair for GlassSlotState.admit when
        the request's GLASS densities differ from the engine config's, else
        None (the engine-default build path, bit-identical to PR 4)."""
        if self.glass is None:
            return None
        gp = e.gp
        d = gp.density if gp.density is not None else self.glass.density
        dd = None
        cap_dd = None
        if self.glass.draft_ratio is not None:
            cap_dd = self.glass.density * self.glass.draft_ratio
            dr = gp.draft_ratio if gp.draft_ratio is not None else self.glass.draft_ratio
            dd = d * dr
        eps = 1e-9
        if abs(d - self.glass.density) <= eps and (
            dd is None or abs(dd - cap_dd) <= eps
        ):
            return None
        return (d, dd)

    def _policy_inputs(self, run: List[LiveRequest], *, with_stops: bool,
                       H_offset_ckpt: bool = False):
        """Fixed-width (``max_slots``) per-request policy vectors for one
        fused scan: the counter-based PRNG position of each slot's first
        emission, the SamplingParams fields, and the early-finish stop set.
        ``with_stops=False`` blanks the stop sets (draft/verify/fix-up
        scans handle stops host-side on the *accepted* tokens only).
        ``H_offset_ckpt=True`` takes positions from the speculative
        checkpoint (the verify scan runs after outputs were provisionally
        extended)."""
        B = self.pool.max_slots
        pos0 = np.zeros((B,), np.int32)
        seeds = np.zeros((B,), np.int32)
        temp = np.ones((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        topp = np.ones((B,), np.float32)
        minp = np.zeros((B,), np.float32)
        gmask = np.ones((B,), bool)
        stop_ids = np.full((B, MAX_STOP_IDS), -1, np.int32)
        sampled = False
        for e in run:
            s = e.slot
            sp = e.sp
            if H_offset_ckpt:
                pos0[s] = e.spec_ckpt.out_len
            else:
                pos0[s] = len(e.outputs) - e.replay_left
            if not sp.is_greedy:
                sampled = True
                gmask[s] = False
                seeds[s] = np.int32(np.uint32(sp.seed))
                temp[s] = sp.temperature
                topk[s] = sp.top_k
                topp[s] = sp.top_p
                minp[s] = sp.min_p
            if with_stops:
                for j, t in enumerate(sp.stop_set):
                    stop_ids[s, j] = t
        return pos0, seeds, temp, topk, topp, minp, gmask, stop_ids, sampled

    # -- lifecycle transitions ----------------------------------------------

    def _output(self, e: LiveRequest, *, finished: bool,
                reason: Optional[str] = None) -> RequestOutput:
        """Build one streaming update for ``e`` and advance its ``emitted``
        cursor (``new_tokens`` is everything not yet reported)."""
        out = RequestOutput(
            uid=e.uid,
            prompt=np.asarray(e.req.prompt, np.int32),
            new_tokens=np.asarray(e.outputs[e.emitted:], np.int32),
            tokens=np.asarray(e.outputs, np.int32),
            finished=finished,
            finish_reason=reason,
            arrival=e.req.arrival,
            admitted_step=e.first_admitted_step,
            finished_step=self.t if finished else -1,
        )
        e.emitted = len(e.outputs)
        return out

    def _stop_reason(self, e: LiveRequest, tok: int) -> str:
        return "eos" if (e.sp is not None and tok == e.sp.eos_token_id) else "stop"

    def _finish(self, slot: int, finished: List[RequestOutput],
                reason: str = "length") -> None:
        e = self.lc.by_slot(slot)
        if e.state is ReqState.SPECULATING:
            # early-finish leak-class guard: pending drafts (provisional
            # tokens, speculative blocks, unverified KV rows) must roll
            # back before FINISHED — SPECULATING's only legal exit is
            # RUNNING, and the lifecycle enforces it
            self._rollback_speculation(e)
        e.finish_reason = reason
        finished.append(self._output(e, finished=True, reason=reason))
        self.pool.free(slot)
        if self.glass_slots is not None:
            self.glass_slots.clear(slot)
        self.lc.to(e, ReqState.FINISHED)
        self._policies.pop(e.uid, None)
        e.slot = -1
        e.pstats = None

    def _preempt(self, e: LiveRequest, kind: Optional[str] = None) -> None:
        """RUNNING/PREFILLING/SPECULATING -> PREEMPTED_{SWAPPED,RECOMPUTE}:
        release the slot and its blocks; swap keeps a bit-exact host copy,
        recompute re-queues for a prompt+prefix replay.

        A mid-speculation victim is first rolled back to its last ACCEPTED
        token.  Without that, ``Scheduler.requeue`` would carry the
        provisional draft tokens in ``outputs`` into the recompute resume,
        which replays ``outputs`` as *forced* decode tokens — the stream
        would contain speculated tokens the target tier never verified (and
        a swap would capture unverified KV rows + over-held blocks)."""
        if e.state is ReqState.SPECULATING:
            self._rollback_speculation(e)
        slot = e.slot
        if e.state is ReqState.PREFILLING:
            kind = "recompute"  # partial prefill: replaying is strictly cheaper
        if kind is None:
            kind = preemption_kind(
                self.preempt_cfg,
                self.pool.held_blocks(slot),
                int(self.pool.lengths[slot]),
            )
        e.preemptions += 1
        if kind == "swap":
            if self.glass_slots is not None:
                e.glass_rows = self.glass_slots.save(slot)
                self.glass_slots.clear(slot)
            e.swap = self.pool.swap_out(slot)
            self.swap_bytes += e.swap.nbytes
            self.swap_store_bytes += e.swap.nbytes
            e.swap_seq = next(self._swap_seq)
            self.lc.to(e, ReqState.PREEMPTED_SWAPPED)
            self._enforce_swap_cap()
        else:
            # tokens whose computation is dropped and must be replayed
            # (prompt progress + generated prefix written so far)
            self.recompute_tokens += int(self.pool.lengths[slot])
            if self.glass_slots is not None:
                self.glass_slots.clear(slot)
            self.pool.free(slot)
            e.pstats = None
            e.prefill_pos = 0
            e.glass_key = None
            e.replay_left = 0
            self.lc.to(e, ReqState.PREEMPTED_RECOMPUTE)
            self.scheduler.requeue(e.req)
        e.slot = -1

    def _enforce_swap_cap(self) -> None:
        """Host swap-store byte cap: while the resident store bytes exceed
        ``PreemptionConfig.swap_store_cap_bytes``, the OLDEST swapped
        request degrades to recompute.  Oldest-first because its store has
        waited longest without a swap-in slot — under sustained pressure it
        is the most likely to be re-queued behind newer work anyway, and
        dropping it frees the most bytes for the least expected re-read."""
        cap = self.preempt_cfg.swap_store_cap_bytes
        if cap is None:
            return
        while self.swap_store_bytes > cap:
            swapped = self.lc.in_state(ReqState.PREEMPTED_SWAPPED)
            if not swapped:
                break
            self._degrade_swapped(min(swapped, key=lambda x: x.swap_seq))

    def _degrade_swapped(self, e: LiveRequest) -> None:
        """PREEMPTED_SWAPPED -> PREEMPTED_RECOMPUTE: drop the host store
        and re-queue for the replay resume (prompt through chunked prefill,
        generated prefix as forced decode tokens — token-identical by the
        recompute guarantee).  Shared device blocks the store kept pinned
        are released like an abort would."""
        self.swap_store_bytes -= e.swap.nbytes
        self.recompute_tokens += e.swap.length
        self.pool.release_swapped(e.swap)
        e.swap = None
        e.swap_seq = -1
        e.glass_rows = None
        e.pstats = None
        e.prefill_pos = 0
        e.glass_key = None
        e.replay_left = 0
        self.lc.to(e, ReqState.PREEMPTED_RECOMPUTE)
        self.scheduler.requeue(e.req)
        self.swap_cap_evictions += 1

    def _preempt_for_capacity(self, protect: Optional[LiveRequest] = None) -> bool:
        """Pick one victim (scheduler policy, mirror of admission order)
        and preempt it.  Returns False when no victim is available."""
        victims = [
            v
            for v in self.lc.in_state(
                ReqState.RUNNING, ReqState.PREFILLING, ReqState.SPECULATING
            )
            if v is not protect
        ]
        vr = self.scheduler.select_victim([v.req for v in victims])
        if vr is None:
            return False
        self._preempt(next(v for v in victims if v.req is vr))
        return True

    def _swap_in_tick(self) -> None:
        """PREEMPTED_SWAPPED / MIGRATING -> RUNNING (or PREFILLING for a
        mid-prefill migration), policy order, as capacity allows.  Swapped
        requests have first claim on freed capacity (the admission filter
        reserves their blocks), and a swap-in keeps the watermark free
        unless nothing is running (then waiting would deadlock)."""
        waiting = sorted(
            self.lc.in_state(ReqState.PREEMPTED_SWAPPED, ReqState.MIGRATING),
            key=lambda e: self.scheduler.admission_key(e.req),
        )
        for e in waiting:
            if not self.pool.n_free_slots:
                return
            reserve = self.pool.watermark if self.pool.active.any() else 0
            if self.pool.has_paged and e.swap.n_blocks + reserve > self.pool.n_available_blocks:
                return
            migrating = e.state is ReqState.MIGRATING
            nbytes = e.swap.nbytes
            slot = self.pool.swap_in(e.swap)
            if slot is None:
                return
            if self.glass_slots is not None:
                self.glass_slots.restore(slot, e.glass_rows)
            e.glass_rows = None
            e.swap = None
            e.slot = slot
            if migrating and e.prefill_pos < len(e.req.prompt):
                # mid-prefill handoff: the splice restored the partial KV /
                # state rows and lengths[slot] == prefill_pos (a chunk
                # boundary); e.pstats carries the partial stat left-fold, so
                # the ordinary prefill tick continues the fold exactly where
                # the source stopped
                e.admitted_step = self.t
                self.lc.to(e, ReqState.PREFILLING)
            else:
                self.lc.to(e, ReqState.RUNNING)
            if not migrating:
                # migration tickets were never charged to this engine's
                # host store (they are transient, first-claim residents)
                self.swap_store_bytes -= nbytes
                e.swap_seq = -1
            self.swap_ins += 1

    def _admit_tick(self) -> None:
        """WAITING / PREEMPTED_RECOMPUTE -> PREFILLING, policy order,
        best-effort under ``_fits``."""
        while self.pool.n_free_slots:
            got = self.scheduler.pop_admissible(self.t, 1, fits=self._fits)
            if not got:
                return
            r = got[0]
            # an existing entry is a PREEMPTED_RECOMPUTE re-admission (its
            # generated prefix rides along for the replay); finished entries
            # are pruned at the FINISHED transition and can't appear here
            e = self.lc.entries.get(r.uid)
            if e is None:
                e = self.lc.add(r)
                # per-request policy, resolved at submit (legacy Requests
                # take the engine defaults); the caller's Request object is
                # never mutated
                e.sp, e.gp = self._policies[r.uid]
            # admission consults the prefix cache: a hit binds the cached
            # chain shared (CoW) and prefill resumes at the fork point from
            # the entry's stat-sum / state-row snapshot.  fork alignment to
            # chunk_tokens keeps resumed chunk boundaries identical to a
            # cold prefill's, so the stat left-fold (and the fused mask it
            # finalizes into) is bit-identical — recompute re-admissions
            # included.
            fork, entries = self.pool.lookup_prefix(r.prompt, self.chunk_tokens)
            slot = None
            if fork:
                rows = (
                    self._rows_needed(r) if self.alloc_mode == "full"
                    else fork + min(self.chunk_tokens, len(r.prompt) - fork)
                )
                slot = self.pool.admit_prefix(rows, entries)
                if slot is None:
                    # ``_fits`` counted the hit chain's own refcount-0 blocks
                    # as reclaimable supply, but binding the chain pins them
                    # — when the private remainder then cannot be allocated,
                    # degrade to a cold admission, whose first-chunk need is
                    # exactly what ``_fits`` verified (its allocation may
                    # evict the very chain we failed to pin)
                    self.pool.cancel_prefix_hit(fork)
                    fork = 0
            if slot is not None:
                e.prefill_pos = fork
                e.cached_rows = fork
                self.pool.lengths[slot] = fork
                tail = entries[-1]
                e.pstats = restore_stat_sums(tail.pstats)
                self.pool.restore_state_rows(slot, tail.state_rows)
            else:
                slot = self.pool.admit(self._first_rows(r))
                if slot is None:
                    # ``_fits`` held, so this is belt-and-braces: requeue
                    # (policy order preserved) and retry on a later tick
                    # rather than corrupting pool state
                    self.scheduler.requeue(r)
                    return
                e.prefill_pos = 0
                e.cached_rows = 0
                e.pstats = None
            self.lc.to(e, ReqState.PREFILLING)
            e.slot = slot
            e.admitted_step = self.t
            if e.first_admitted_step < 0:
                e.first_admitted_step = self.t
                self.admission_waits.append(self.t - r.arrival)

    # -- tick work ----------------------------------------------------------

    def _prefill_tick(self, finished: List[RequestOutput]) -> bool:
        """Run ONE bounded chunk for the oldest mid-prefill request."""
        pre = self.lc.in_state(ReqState.PREFILLING)
        if not pre:
            return False
        e = min(pre, key=lambda e: (e.admitted_step, e.uid))
        r = e.req
        slot = e.slot
        pos = e.prefill_pos
        # chunks never cross the prompt boundary: GLASS running-sum stats
        # must cover EXACTLY the prompt tokens so a recompute replay (same
        # boundaries, same tokens) reproduces the identical fused mask
        T = min(self.chunk_tokens, len(r.prompt) - pos)
        while not self.pool.ensure_capacity(slot, pos + T):
            if not self._preempt_for_capacity(protect=e):
                # sole in-flight request: cannot happen (submit validates the
                # full need) — recompute-preempt as a safe fallback
                self._preempt(e, "recompute")
                return False
        toks = jnp.asarray(np.asarray(r.prompt[pos : pos + T], np.int32))[None]
        # gather width covers the *prefilled prefix* (every page written so
        # far plus this chunk), not the request's full allocation — early
        # chunks of a long-generation request must not attend max_len rows
        nb = _pow2_bucket(-(-(pos + T) // self.pool.block_size), self.pool.nb_max)
        btab = jnp.asarray(self.pool.block_table[slot : slot + 1, :nb])
        last, arena, stats = self._chunk(
            self.params, self.pool.cache, toks, jnp.asarray([pos], jnp.int32),
            btab, jnp.int32(slot),
        )
        self.pool.cache = arena
        self.pool.lengths[slot] = pos + T
        e.prefill_pos = pos + T
        # e.pstats is the FULL left-fold over [0, pos+T): on a cache hit the
        # restored snapshot already covers [0, fork), so merging each chunk
        # keeps the fold identical to a cold prefill's (same additions, same
        # association — merge_stat_sums docstring)
        e.pstats = merge_stat_sums(e.pstats, stats)
        end = pos + T
        # register the prefilled prefix: full blocks become cache entries
        # immediately (concurrent arrivals may hit a still-prefilling
        # request's prefix).  An entry is resumable only at a block+chunk
        # aligned boundary — there the stat fold and recurrent state match
        # what a cold prefill would hold at the same position.
        if self.pool.prefix_cache is not None:
            resumable = (
                end % self.pool.block_size == 0 and end % self.chunk_tokens == 0
            )
            self.pool.register_prefix(
                slot, r.prompt, end,
                resumable=resumable,
                pstats=snapshot_stat_sums(e.pstats) if resumable else None,
                state_rows=self.pool.save_state_rows(slot) if resumable else None,
            )
        self.max_prefill_tokens_per_tick = max(self.max_prefill_tokens_per_tick, T)
        if pos + T == len(r.prompt):  # final chunk: finalize GLASS + first token
            if self.glass_slots is not None:
                rows = self.glass_slots.admit(
                    [slot], [e.pstats], overrides=[self._glass_override(e)]
                )
                if self._mode == "block_sparse":
                    # host copy of the (L, nb_keep) active-block list AND
                    # its tile scales: the group-by key for the shared-list
                    # decode kernel — rows may only batch through one shared
                    # grid when both their lists and their per-request
                    # density scales coincide
                    e.glass_key = (
                        np.asarray(rows["idx"][:, 0]).tobytes()
                        + np.asarray(rows["scale"][:, 0]).tobytes()
                    )
            e.pstats = None
            self.lc.to(e, ReqState.RUNNING)
            if e.outputs:
                # recompute resume: the generated prefix is replayed through
                # decode as forced tokens — nothing is re-sampled (and the
                # counter-based draws would regenerate it bit-identically
                # anyway)
                e.pending = e.outputs[0]
                e.replay_left = len(e.outputs) - 1
            else:
                first = self._first_token_for(e, np.asarray(last[0], np.float32))
                e.outputs = [first]
                e.pending = first
                e.rng_pos = 1
                if first in e.sp.stop_set:
                    self._finish(slot, finished, self._stop_reason(e, first))
                elif len(e.outputs) >= r.max_new:
                    self._finish(slot, finished, "length")
        return True

    def _horizon(self, prefill_pending: bool) -> int:
        """Largest safe fused-decode length: 1 while any prefill is pending
        (chunks must interleave), else bounded by the first possible eviction
        and — when capacity could accept it — the next queued arrival."""
        if prefill_pending:
            return 1
        run = self.lc.in_state(ReqState.RUNNING)
        h = min(e.req.max_new - len(e.outputs) + e.replay_left for e in run)
        if self.pool.n_free_slots and len(self.scheduler):
            # only arrivals that could actually be admitted bound the chunk:
            # an arrived-but-unfitting request (block pressure) can only be
            # admitted after an eviction, and h is already bounded by the
            # first eviction — clamping on it would degrade decode to H=1
            na = min(
                (r.arrival for r in self.scheduler.queue if self._fits(r)),
                default=None,
            )
            if na is not None:
                h = min(h, max(1, na - self.t))
        h = min(h, self.decode_chunk)
        p = 1
        while p * 2 <= h:
            p *= 2
        return p

    def _growth_need(self, run: List[LiveRequest], H: int) -> int:
        """Blocks the pool must supply for every running slot to advance H
        tokens (allocate-on-boundary growth past current holdings)."""
        return sum(
            max(
                0,
                self.pool.blocks_needed(int(self.pool.lengths[e.slot]) + H)
                - self.pool.held_blocks(e.slot),
            )
            for e in run
        )

    def _ffn_grouping(self, run: List[LiveRequest]):
        """Group decode rows by identical active-block lists (block_sparse
        mode): rows in a group >= 2 batch through the shared-list
        ``glass_ffn`` kernel; everything else (singletons, inactive and
        mid-prefill rows) falls back to rowwise.  Returns (static group
        sizes, row permutation) or ((), None)."""
        if self._mode != "block_sparse":
            return (), None
        keys: List[Optional[bytes]] = [None] * self.pool.max_slots
        for e in run:
            keys[e.slot] = e.glass_key
        groups: Dict[bytes, List[int]] = {}
        for s in range(self.pool.max_slots):
            if keys[s] is not None:  # inactive rows never justify a group:
                # their output is discarded, and letting them form one would
                # change the static `groups` signature (and recompile the
                # decode scan) on every occupancy change
                groups.setdefault(keys[s], []).append(s)
        multi = [g for g in groups.values() if len(g) > 1]
        if not multi:
            return (), None
        # canonicalize: sizes sorted descending, so tick-to-tick reshuffles
        # that only permute the groups reuse one compiled decode variant —
        # the static-signature space is partitions of max_slots (22 at 8
        # slots), not compositions (128)
        multi.sort(key=lambda g: (-len(g), g[0]))
        in_multi = {s for g in multi for s in g}
        rest = [s for s in range(self.pool.max_slots) if s not in in_multi]
        perm = [s for g in multi for s in g] + rest
        return tuple(len(g) for g in multi), np.asarray(perm, np.int32)

    def _scan_inputs(self, run: List[LiveRequest], H: int):
        """Fixed-width (``max_slots``) batch arrays for one fused scan over
        ``run``: decoding mask, per-slot lengths and first tokens, and a
        gather-width-bucketed block table covering every participant's rows
        plus ``H`` new ones (non-participants trash-redirected)."""
        B = self.pool.max_slots
        decoding = np.zeros((B,), bool)
        lengths = np.zeros((B,), np.int32)
        toks = np.zeros((B,), np.int32)
        for e in run:
            s = e.slot
            decoding[s] = True
            lengths[s] = self.pool.lengths[s]
            toks[s] = e.pending
        if self.pool.has_paged:
            need = int(max(lengths[e.slot] + H for e in run))
            nb = _pow2_bucket(-(-need // self.pool.block_size), self.pool.nb_max)
            btab = np.where(
                decoding[:, None], self.pool.block_table[:, :nb], 0
            ).astype(np.int32)
        else:
            btab = np.zeros((B, 1), np.int32)
        return decoding, lengths, toks, btab

    # -- speculative decode (draft tier -> multi-token verify -> rollback) ---

    def _spec_round(self, run: List[LiveRequest]) -> Tuple[List[LiveRequest], int]:
        """Participants + draft length for this tick's speculative round.

        Requests opt in per their own ``GlassParams.spec_k`` (engine
        ``spec_k`` is just the default), so ``spec_k=0`` requests — and
        recompute replays still re-feeding forced tokens, and requests
        within one token of finishing — simply sit the round out and take
        a plain H=1 decode in the SAME tick.  The round's draft length is
        the minimum over participants of ``min(spec_k, remaining - 1)``: a
        round emits up to k+1 tokens per slot and its verify writes k+1 KV
        rows, which must stay inside the request's row need
        (``len(prompt) + max_new - 1`` rows, validated at submit, also
        bounds the block table)."""
        if self.glass_slots is None or not self.glass_slots.tiered or not run:
            return [], 0
        parts = [
            e for e in run
            if e.gp.spec_k and not e.replay_left
            and e.req.max_new - len(e.outputs) >= 2
        ]
        if not parts:
            return [], 0
        k = min(
            min(e.gp.spec_k, e.req.max_new - len(e.outputs) - 1) for e in parts
        )
        return parts, max(0, k)

    def _spec_possible(self, run: List[LiveRequest]) -> int:
        """Compat helper (the state-invariant suite drives rounds by hand):
        the round's draft length when EVERY member of ``run`` participates,
        else 0 — the pre-partition semantics of :meth:`_spec_round`."""
        parts, k = self._spec_round(run)
        return k if len(parts) == len(run) else 0

    def _spec_capacity(self, run: List[LiveRequest], k: int) -> int:
        """Reserve ``k + 1`` KV rows of growth for every participant,
        halving ``k`` under block pressure (mirroring the fused-decode
        horizon shrink).  Never preempts: if even ``k = 1`` (2 rows of
        growth per slot) does not fit, it returns 0 and this tick falls
        back to plain decode, whose H=1 needs HALF the growth — evicting a
        victim here would drop work the non-speculative engine would have
        kept running (the plain path escalates to preemption itself only
        when 1 row per slot still does not fit)."""
        if not (self.pool.has_paged and self.alloc_mode == "incremental"):
            return k  # full-need admission reserved the worst case
        while k > 1 and self._growth_need(run, k + 1) > self.pool.n_available_blocks:
            k //= 2
        if self._growth_need(run, k + 1) > self.pool.n_available_blocks:
            return 0
        for e in run:
            if not self.pool.ensure_capacity(e.slot, int(self.pool.lengths[e.slot]) + k + 1):
                # the fit was measured against reclaimable slack that can
                # transiently exceed what eviction can drain (see
                # n_reclaimable_blocks) — fall back to plain decode, whose
                # growth path preempts if even H=1 cannot be supplied
                return 0
        return k

    def _spec_draft(self, run: List[LiveRequest], k: int) -> None:
        """Checkpoint every participant (RUNNING -> SPECULATING) and propose
        ``k`` draft tokens per slot under the DRAFT tier in one fused scan.

        Draft KV rows land in the request's real blocks — the verify pass
        overwrites every one of them with target-tier values, so no draft
        numerics survive — and draft-advanced recurrent state is restored
        from the checkpoint before verification.  Draft tokens are appended
        to ``outputs`` PROVISIONALLY (``spec_len`` marks them): nothing may
        read them as ground truth until the target tier accepts them."""
        for e in run:
            n = int(self.pool.lengths[e.slot])
            e.spec_ckpt = SpecCheckpoint(
                rows=n, ensured=n + k + 1, out_len=len(e.outputs),
                pending=e.pending, state_rows=self.pool.save_state_rows(e.slot),
            )
            self.lc.to(e, ReqState.SPECULATING)
        decoding, lengths, toks, btab = self._scan_inputs(run, k + 1)
        pos0, seeds, temp, topk, topp, minp, gmask, stop_ids, sampled = (
            self._policy_inputs(run, with_stops=False)
        )
        B = self.pool.max_slots
        # sampled slots draft with the SAME counter-based keys the target
        # verdict will use — proposal j for position out_len + j draws key
        # (seed, out_len + j) from the DRAFT logits, so a proposal matches
        # the verdict exactly when both tiers would emit the same token
        seq, _, _, arena = self._decode(
            self.params, self.pool.cache, jnp.asarray(lengths), jnp.asarray(toks),
            jnp.asarray(btab), jnp.asarray(decoding), self.glass_slots.draft_arena,
            jnp.zeros((k, B), jnp.int32), jnp.zeros((k, B), bool),
            jnp.zeros((B,), jnp.int32),
            jnp.asarray(pos0), jnp.asarray(seeds), jnp.asarray(temp),
            jnp.asarray(topk), jnp.asarray(topp), jnp.asarray(minp),
            jnp.asarray(gmask), jnp.asarray(stop_ids),
            (), sampled,
        )
        self.pool.cache = arena
        seq = np.asarray(seq)  # (k, B) draft proposals d_1..d_k
        for e in run:
            # provisional: rng_pos intentionally does NOT advance until the
            # target tier accepts
            e.outputs.extend(int(x) for x in seq[:, e.slot])
            e.spec_len = k

    def _spec_verify(self, run: List[LiveRequest], k: int,
                     finished: List[RequestOutput]) -> None:
        """Target-tier verification of all ``k + 1`` positions in ONE
        forced-token scan — the recompute-replay machinery re-purposed:
        step ``j`` feeds the round's j-th input token (``pending`` then the
        drafts) and the scan's pre-override verdict IS the target verdict
        ``t_j`` — the greedy argmax, or for seeded requests the
        counter-based positional sample from the pre-override logits (a
        pure function of (seed, position, logits), so draft/target
        exactness holds under sampling exactly as under greedy).  Accept
        the longest prefix with ``d_{j+1} == t_j`` plus the bonus token
        ``t_a``, then roll back everything past the accepted frontier: fix
        up recurrent state from the pre-draft carry, un-scatter rejected
        KV rows, release speculative blocks.  Accepted tokens that hit the
        request's stop set finish it early (truncated at the stop token,
        blocks freed this tick)."""
        has_state = self.pool.has_state
        if has_state:
            # the draft advanced recurrent state k steps under the draft
            # tier; verification must start from the pre-draft carry
            for e in run:
                self.pool.restore_state_rows(e.slot, e.spec_ckpt.state_rows)
        decoding, lengths, toks, btab = self._scan_inputs(run, k + 1)
        pos0, seeds, temp, topk, topp, minp, gmask, stop_ids, sampled = (
            self._policy_inputs(run, with_stops=False, H_offset_ckpt=True)
        )
        B = self.pool.max_slots
        ftoks = np.zeros((k + 1, B), np.int32)
        fmask = np.zeros((k + 1, B), bool)
        for e in run:
            ck = e.spec_ckpt
            toks[e.slot] = ck.pending  # unchanged during draft, but explicit
            for j in range(k):
                ftoks[j, e.slot] = e.outputs[ck.out_len + j]
                fmask[j, e.slot] = True
        groups, perm = self._ffn_grouping(run)
        if perm is None:
            perm = np.zeros((B,), np.int32)
        if self._verify_parallel:
            # ONE T = k+1 forward instead of the k+1-step scan: the feed is
            # fully known up front (pending + drafts, all forced), and the
            # per-query kernel grid keeps logits bitwise equal to the scan
            feed = np.zeros((B, k + 1), np.int32)
            feed[:, 0] = toks
            for e in run:
                ck = e.spec_ckpt
                for j in range(k):
                    feed[e.slot, j + 1] = e.outputs[ck.out_len + j]
            tgt, arena = self._pverify(
                self.params, self.pool.cache, jnp.asarray(lengths),
                jnp.asarray(feed), jnp.asarray(btab), self.glass_slots.arena,
                jnp.asarray(perm), jnp.asarray(pos0), jnp.asarray(seeds),
                jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(topp),
                jnp.asarray(minp), jnp.asarray(gmask),
                groups, sampled,
            )
        else:
            _, tgt, _, arena = self._decode(
                self.params, self.pool.cache, jnp.asarray(lengths), jnp.asarray(toks),
                jnp.asarray(btab), jnp.asarray(decoding), self.glass_slots.arena,
                jnp.asarray(ftoks), jnp.asarray(fmask), jnp.asarray(perm),
                jnp.asarray(pos0), jnp.asarray(seeds), jnp.asarray(temp),
                jnp.asarray(topk), jnp.asarray(topp), jnp.asarray(minp),
                jnp.asarray(gmask), jnp.asarray(stop_ids),
                groups, sampled,
            )
        self.pool.cache = arena
        tgt = np.asarray(tgt)  # (k+1, B) target-tier verdicts
        self.spec_ticks += 1
        self.spec_slot_ticks += len(run)
        self.spec_drafted += k * len(run)
        fixups: Dict[int, List[Tuple[int, SpecCheckpoint, List[int]]]] = {}
        to_finish: List[Tuple[int, str]] = []
        for e in run:
            s = e.slot
            ck = e.spec_ckpt
            drafts = e.outputs[ck.out_len :]
            a = 0
            while a < k and drafts[a] == int(tgt[a, s]):
                a += 1
            accepted = [int(tgt[j, s]) for j in range(a + 1)]
            if a < k:
                self.spec_rollbacks += 1
                self.spec_rolled_back_rows += ck.ensured - (ck.rows + a + 1)
                if has_state:
                    fixups.setdefault(a + 1, []).append((s, ck, accepted))
            self.pool.rollback_rows(s, ck.rows + a + 1, ck.ensured)
            if self.alloc_mode == "incremental":
                # full-need admission reserved (and keeps) the whole
                # footprint — shrinking would free blocks nothing ever
                # re-allocates, sending later KV writes to the trash block
                self.pool.shrink_to(s, ck.rows + a + 1)
            self.pool.lengths[s] = ck.rows + a + 1
            del e.outputs[ck.out_len :]
            e.outputs.extend(accepted)
            e.pending = accepted[-1]
            e.rng_pos = len(e.outputs)  # drafts committed: counter catches up
            e.spec_len = 0
            e.spec_ckpt = None
            self.lc.to(e, ReqState.RUNNING)
            stop_i = next(
                (i for i, t2 in enumerate(accepted) if t2 in e.sp.stop_set), None
            )
            # telemetry counts tokens that actually reach the stream: a
            # stop hit discards the accepted tail, so it must not inflate
            # the acceptance rate (accepted[a] is the bonus token)
            kept = len(accepted) if stop_i is None else stop_i + 1
            self.spec_accepted += min(a, kept)
            self.spec_emitted += kept
            if stop_i is not None:
                del e.outputs[ck.out_len + stop_i + 1 :]
                e.rng_pos = len(e.outputs)
                to_finish.append((s, self._stop_reason(e, e.outputs[-1])))
            elif len(e.outputs) >= e.req.max_new:
                to_finish.append((s, "length"))
        # state fix-ups BEFORE finishes: a stop-finishing rolled-back slot
        # must not have its (freed, zeroed) state row written afterwards
        for H, group in sorted(fixups.items()):
            self._spec_state_fixup(H, group)
        for s, reason in to_finish:
            self._finish(s, finished, reason)

    def _spec_state_fixup(
        self, H: int, group: List[Tuple[int, SpecCheckpoint, List[int]]]
    ) -> None:
        """Recurrent families only: the verify scan advanced the state
        ``k + 1`` steps but a rolled-back slot only had ``H = a + 1`` real
        feeds.  Restore each slot's pre-draft carry and replay exactly the
        accepted feeds (forced) through the same scan body — the state
        lands bit-identical to never having speculated.  Slots that share
        an accepted length batch into ONE scan; the scan length must equal
        the feed count, so the jit variants are bounded by ``spec_k + 1``
        (they cannot be pow2-bucketed like the gather widths — padding
        would advance the state past the accepted frontier).  The replay
        rewrites accepted KV rows with identical values (the rejected rows
        it would have read are excluded by the ``kv_len`` mask, so the
        earlier un-scatter does not perturb it); every other slot's table
        entry is trash-redirected and its state row is guarded by the
        decoding mask, so nothing else moves."""
        B = self.pool.max_slots
        decoding = np.zeros((B,), bool)
        lengths = np.zeros((B,), np.int32)
        toks = np.zeros((B,), np.int32)
        ftoks = np.zeros((H, B), np.int32)
        fmask = np.zeros((H, B), bool)
        rows_max = 1
        for slot, ck, accepted in group:
            self.pool.restore_state_rows(slot, ck.state_rows)
            decoding[slot] = True
            lengths[slot] = ck.rows
            toks[slot] = ck.pending
            rows_max = max(rows_max, ck.rows + H)
            for j in range(H - 1):
                ftoks[j, slot] = accepted[j]
                fmask[j, slot] = True
        if self.pool.has_paged:
            nb = _pow2_bucket(-(-rows_max // self.pool.block_size), self.pool.nb_max)
            btab = np.where(
                decoding[:, None], self.pool.block_table[:, :nb], 0
            ).astype(np.int32)
        else:
            btab = np.zeros((B, 1), np.int32)
        # sampled=False: the replay's emissions are discarded (every real
        # feed is forced), so the greedy-compiled variant serves it
        _, _, _, arena = self._decode(
            self.params, self.pool.cache, jnp.asarray(lengths), jnp.asarray(toks),
            jnp.asarray(btab), jnp.asarray(decoding), self.glass_slots.arena,
            jnp.asarray(ftoks), jnp.asarray(fmask),
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
            jnp.ones((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
            jnp.ones((B,), jnp.float32), jnp.zeros((B,), jnp.float32),
            jnp.ones((B,), bool), jnp.full((B, MAX_STOP_IDS), -1, jnp.int32),
            (), False,
        )
        self.pool.cache = arena

    def _rollback_speculation(self, e: LiveRequest) -> None:
        """SPECULATING -> RUNNING by discarding the round entirely: restore
        the pre-draft state carry, un-scatter every row the round wrote,
        release speculative block growth (reverse order, so the allocator
        stack is exactly pre-speculation), and slice the provisional draft
        tokens off ``outputs`` — downstream consumers (swap stores,
        recompute's forced-token replay) must only ever see accepted
        tokens."""
        ck = e.spec_ckpt
        self.pool.restore_state_rows(e.slot, ck.state_rows)
        self.pool.rollback_rows(e.slot, ck.rows, ck.ensured)
        if self.alloc_mode == "incremental":
            # see _spec_verify: full-need reservations must stay allocated
            self.pool.shrink_to(e.slot, ck.rows)
        self.pool.lengths[e.slot] = ck.rows
        self.spec_rolled_back_rows += ck.ensured - ck.rows
        self.spec_rollbacks += 1
        del e.outputs[ck.out_len :]
        e.pending = ck.pending
        e.rng_pos = len(e.outputs)  # counter rewinds with the outputs
        e.spec_len = 0
        e.spec_ckpt = None
        self.lc.to(e, ReqState.RUNNING)

    @property
    def spec_telemetry(self) -> Dict[str, float]:
        """Speculative-decode acceptance and rollback counters."""
        return dict(
            spec_ticks=self.spec_ticks,
            drafted_tokens=self.spec_drafted,
            accepted_tokens=self.spec_accepted,
            emitted_tokens=self.spec_emitted,
            draft_acceptance_rate=self.spec_accepted / max(self.spec_drafted, 1),
            accepted_tokens_per_tick=self.spec_emitted / max(self.spec_slot_ticks, 1),
            rollbacks=self.spec_rollbacks,
            rolled_back_rows=self.spec_rolled_back_rows,
        )

    def _fit_growth(self, run: List[LiveRequest], H: int
                    ) -> Tuple[List[LiveRequest], int]:
        """Allocate-on-boundary growth for one fused chunk: shrink H before
        shrinking the working set (a smaller H needs fewer boundary
        crossings than a preemption), then preempt victims until the
        remaining ``run`` fits.  Returns the surviving run and H.

        The fit check measures supply against reclaimable cache slack,
        which can transiently exceed what eviction can actually drain
        (see ``n_reclaimable_blocks``) — so a failed allocation after a
        passing check is recoverable pressure, answered by preempting
        another victim and re-fitting, not an invariant violation."""
        if not (self.pool.has_paged and self.alloc_mode == "incremental"):
            return run, H
        while H > 1 and self._growth_need(run, H) > self.pool.n_available_blocks:
            H //= 2
        while True:
            while self._growth_need(run, H) > self.pool.n_available_blocks:
                if not self._preempt_for_capacity():
                    break
                run = [e for e in run if e.state is ReqState.RUNNING]
                if not run:
                    return [], H
            if all(
                self.pool.ensure_capacity(e.slot, int(self.pool.lengths[e.slot]) + H)
                for e in run
            ):
                return run, H
            # partial growth is harmless (extra held blocks serve the next
            # tick); each retry preempts one victim, so this terminates
            if not self._preempt_for_capacity():
                return [], H
            run = [e for e in run if e.state is ReqState.RUNNING]
            if not run:
                return [], H

    def _plain_decode(self, run: List[LiveRequest], H: int,
                      finished: List[RequestOutput]) -> None:
        """One fused H-step decode scan over ``run`` (growth already
        ensured): per-slot sampling policy, forced replay re-feeds, and
        in-scan stop detection — a slot whose emitted token hits its stop
        set is truncated at the hit and finished (blocks freed) this tick."""
        B = self.pool.max_slots
        decoding, lengths, toks, btab = self._scan_inputs(run, H)
        pos0, seeds, temp, topk, topp, minp, gmask, stop_ids, sampled = (
            self._policy_inputs(run, with_stops=True)
        )
        ftoks = np.zeros((H, B), np.int32)
        fmask = np.zeros((H, B), bool)
        for e in run:
            s = e.slot
            f = min(H, e.replay_left)
            if f:  # forced re-feeds: outputs[n - replay_left : ...]
                start = len(e.outputs) - e.replay_left
                for j in range(f):
                    ftoks[j, s] = e.outputs[start + j]
                    fmask[j, s] = True
        groups, perm = self._ffn_grouping(run)
        if perm is None:
            perm = np.zeros((B,), np.int32)  # unused when groups == ()
        extra = self.glass_slots.arena if self.glass_slots is not None else None
        seq, _, hits, arena = self._decode(
            self.params, self.pool.cache, jnp.asarray(lengths), jnp.asarray(toks),
            jnp.asarray(btab), jnp.asarray(decoding), extra,
            jnp.asarray(ftoks), jnp.asarray(fmask), jnp.asarray(perm),
            jnp.asarray(pos0), jnp.asarray(seeds), jnp.asarray(temp),
            jnp.asarray(topk), jnp.asarray(topp), jnp.asarray(minp),
            jnp.asarray(gmask), jnp.asarray(stop_ids),
            groups, sampled,
        )
        self.pool.cache = arena
        seq = np.asarray(seq)  # (H, B)
        hits = np.asarray(hits)  # (H, B) in-scan stop detections
        self.slot_steps += H * len(run)
        # telemetry: grouped rows are live by construction (_ffn_grouping
        # keys only RUNNING slots); memory integrates POST-growth holdings —
        # blocks allocated for this chunk's boundary crossings count for
        # every tick they are held
        self.grouped_rows += H * sum(groups)
        for e in run:
            s = e.slot
            self.pool.lengths[s] += H
            f = min(H, e.replay_left)
            e.replay_left -= f
            new = [int(x) for x in seq[f:, s]]
            hit_steps = np.nonzero(hits[f:, s])[0]
            if hit_steps.size:
                new = new[: int(hit_steps[0]) + 1]
            e.outputs.extend(new)
            e.pending = int(seq[-1, s])
            e.rng_pos = len(e.outputs)
            if hit_steps.size:
                self._finish(s, finished, self._stop_reason(e, e.outputs[-1]))
            elif len(e.outputs) >= e.req.max_new:
                self._finish(s, finished, "length")

    def _decode_tick(self, finished: List[RequestOutput], prefill_pending: bool) -> bool:
        run = self.lc.in_state(ReqState.RUNNING)
        if not run:
            return False
        spec_run, k = self._spec_round(run)
        if k:
            k = self._spec_capacity(spec_run, k)
        if k:
            self._spec_draft(spec_run, k)
            self._spec_verify(spec_run, k, finished)
            # occupancy telemetry: a speculative round runs 2k+1 scan steps
            # (k draft + k+1 verify) per participating slot; memory
            # integrates post-rollback holdings for this tick
            self.slot_steps += (2 * k + 1) * len(spec_run)
            self.kv_row_ticks += self.pool.blocks_in_use * self.pool.block_size
            # spec_k=0 requests (and replays, and requests one token from
            # finishing) interleave in the SAME tick: a plain H=1 decode
            # over the non-participants
            spec_ids = {id(e) for e in spec_run}
            others = [
                e for e in self.lc.in_state(ReqState.RUNNING)
                if id(e) not in spec_ids
            ]
            if others:
                others, _ = self._fit_growth(others, 1)
                if others:
                    self._plain_decode(others, 1, finished)
            self.t += 1
            return True
        H = self._horizon(prefill_pending)
        run, H = self._fit_growth(run, H)
        if not run:
            return False
        # memory telemetry: POST-growth holdings — blocks allocated for this
        # chunk's boundary crossings count for every tick they are held
        self.kv_row_ticks += H * self.pool.blocks_in_use * self.pool.block_size
        self._plain_decode(run, H, finished)
        self.t += H
        return True

    def step(self) -> List[RequestOutput]:
        """One engine tick: a thin driver over the lifecycle — swap-ins
        first (they have first claim on freed capacity), then admissions
        (policy order, best-effort under the watermark-aware filter), at
        most one bounded prefill chunk, then the largest provably safe
        fused decode chunk (speculative round + plain decode for the
        non-participants), preempting victims if growth outruns the pool.

        Returns the tick's :class:`RequestOutput` stream: one
        ``finished=True`` entry per request that completed (``length |
        stop | eos``; :meth:`abort` returns its own), plus one live delta
        (``new_tokens``) per request that accepted tokens this tick —
        consume them as they arrive for streaming generation."""
        finished: List[RequestOutput] = []
        t0 = self.t
        self._swap_in_tick()
        self._admit_tick()
        prefilled = self._prefill_tick(finished)
        self._swap_in_tick()  # a finished max_new==1 request frees capacity
        self._admit_tick()
        # memory telemetry: blocks held by every in-flight request (decoding
        # AND mid-prefill); _decode_tick charges its own ticks post-growth,
        # this snapshot covers prefill-only / idle advances
        rows_now = self.pool.blocks_in_use * self.pool.block_size
        prefill_pending = bool(self.lc.in_state(ReqState.PREFILLING))
        decoded = self._decode_tick(finished, prefill_pending or prefilled)
        if not decoded:
            if prefilled:
                self.t += 1
            else:
                na = self.scheduler.next_arrival()
                self.t = max(self.t + 1, na if na is not None else self.t + 1)
            self.kv_row_ticks += (self.t - t0) * rows_now
        # streaming deltas for everything still live that grew this tick
        # (accepted tokens only: SPECULATING never persists across a tick,
        # so provisional drafts are never reported)
        for e in self.lc.in_state(
            ReqState.PREFILLING, ReqState.RUNNING,
            ReqState.PREEMPTED_SWAPPED, ReqState.PREEMPTED_RECOMPUTE,
            ReqState.MIGRATING,
        ):
            if len(e.outputs) > e.emitted:
                finished.append(self._output(e, finished=False))
        return finished
