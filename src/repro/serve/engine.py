"""Batched serving engine with first-class GLASS integration.

Request lifecycle (paper Fig. 2 right):

  1. prefill the (padded) prompt batch, collecting local activation stats;
  2. fuse local stats with the offline global prior -> per-layer masks;
  3. gather compact FFN weights once;
  4. steady-state decode with the compact weights (density * FLOPs/bytes).

``glass=None`` serves dense.  ``mode="masked"`` keeps full weights and
multiplies the mask in (the block-sparse-kernel deployment); ``"compact"``
gathers (the fast-memory-residency deployment).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fusion import GlassConfig
from ..core.glass import build_masks, compact_params
from ..models.api import Model
from .sampling import sample


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, max_new)
    logits_seq: Optional[np.ndarray]  # (B, max_new, V) when requested
    masks: Optional[object]


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        glass_mode: str = "compact",  # compact | masked
    ):
        self.model = model
        self.params = params
        self.glass = glass
        self.prior = global_prior
        self.glass_mode = glass_mode
        if glass is not None:
            assert global_prior is not None, "GLASS needs the offline prior"

    def generate(
        self,
        prompts: jax.Array,  # (B, S) int32, right-aligned/padded by caller
        max_new: int,
        *,
        rng: Optional[jax.Array] = None,
        temperature: float = 0.0,  # 0 => greedy
        top_k: int = 0,
        return_logits: bool = False,
    ) -> GenerationResult:
        model, params = self.model, self.params
        B, S = prompts.shape
        logits, cache, stats = jax.jit(
            lambda p, t: model.prefill(p, {"tokens": t}, S + max_new)
        )(params, prompts)

        masks = None
        compact = None
        ffn_masks = None
        if self.glass is not None:
            masks = build_masks(stats, self.prior, self.glass)
            if self.glass_mode == "compact":
                compact = compact_params(model, params, masks.idx)
            else:
                ffn_masks = masks.mask

        rng = rng if rng is not None else jax.random.key(0)

        def pick(r, lg):
            if temperature <= 0.0:
                return jnp.argmax(lg, axis=-1).astype(jnp.int32)
            return sample(r, lg, temperature=temperature, top_k=top_k).astype(jnp.int32)

        @jax.jit
        def decode_loop(params, cache, first_tok, rng):
            def body(carry, i):
                cache, tok, rng = carry
                rng, krng = jax.random.split(rng)
                lg, cache = model.decode_step(
                    params, tok[:, None], cache, S + i,
                    ffn_masks=ffn_masks, compact_layers=compact,
                )
                nxt = pick(krng, lg[:, -1].astype(jnp.float32))
                return (cache, nxt, rng), (nxt, lg[:, -1] if return_logits else jnp.zeros((B, 0)))

            (_, _, _), (toks, lgs) = jax.lax.scan(
                body, (cache, first_tok, rng), jnp.arange(max_new, dtype=jnp.int32)
            )
            return toks.T, jnp.swapaxes(lgs, 0, 1)

        rng, krng = jax.random.split(rng)
        first = pick(krng, logits[:, -1].astype(jnp.float32))
        toks, lgs = decode_loop(params, cache, first, rng)
        out_tokens = np.asarray(jnp.concatenate([first[:, None], toks[:, :-1]], axis=1))
        return GenerationResult(
            tokens=out_tokens,
            logits_seq=np.asarray(lgs) if return_logits else None,
            masks=masks,
        )
