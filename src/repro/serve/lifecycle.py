"""Per-request lifecycle state machine for the paged serving engine.

Every request the :class:`~repro.serve.engine.PagedEngine` touches owns one
:class:`LiveRequest` entry that moves through an explicit state machine::

    WAITING ──▶ PREFILLING ──▶ RUNNING ◀──▶ SPECULATING
                   │   ▲ │        │  ▲      (draft k + verify k+1; commit
                   │   │ │        │  │       or rollback returns to RUNNING)
                   │   │ │        │  │ (swap-in restores KV bit-exact)
                   │   │ │        ▼  │
                   │   │ └─▶ PREEMPTED_SWAPPED ──▶ MIGRATING
                   │   │          │       (host store handed to another
                   │   │          │        engine; swap-in there resumes
                   │   │          │        RUNNING / PREFILLING bit-exact)
                   │   │          ▼ (requeue; replay prompt + generated
                   │   └── PREEMPTED_RECOMPUTE     prefix through prefill)
                   └──────────────▲            RUNNING ──▶ FINISHED

``SPECULATING`` is the self-speculative decode sub-phase: the slot holds
*unverified* draft KV rows, provisionally extended outputs, and possibly
blocks allocated past the accepted frontier.  It can only exit back to
RUNNING — the engine rolls the speculation back to the last accepted token
(restore the pre-draft state carry, un-scatter rejected rows, release
speculative blocks, slice provisional outputs) before any preemption or
finish, so swap/recompute resume paths never see speculated state.

A request can now also reach FINISHED *early*: per-slot EOS/stop-token
detection in the decode scan (``finish_reason`` "eos"/"stop") or an
explicit ``engine.abort`` ("aborted") — from PREFILLING, RUNNING,
PREEMPTED_SWAPPED (the host swap store is dropped) or PREEMPTED_RECOMPUTE
(the queued replay is cancelled).  The same rule as preemption applies to
a SPECULATING request: it must roll back its pending drafts (releasing
speculative blocks and provisional tokens) and pass through RUNNING first
— the FINISHED-via-stop transition enforces the early-finish leak class
away.

All resource transitions (slot binding, block allocation, swap stores,
GLASS per-slot rows) happen *at* a state transition, never ad hoc: the
engine tick asks the lifecycle for this tick's swap-in / admission /
prefill / decode work and the :class:`Lifecycle` enforces that only legal
transitions occur.  Illegal transitions raise — a preempted request that
was never swapped out cannot be swapped in, a finished request cannot be
preempted, and so on.

Preemption comes in two flavors, chosen per victim by a cost model
(:func:`preemption_kind`):

* **swap** — the request's KV blocks are copied to a host-side store and
  freed (:meth:`BlockPool.swap_out`); resuming copies them back into
  freshly allocated blocks, bit-identical, so decode continues as if
  nothing happened.  Cost ∝ blocks held (bytes moved twice).
* **recompute** — the blocks are dropped and the request re-queued; on
  re-admission the prompt is replayed through the existing chunked
  prefill (running-sum GLASS stats reproduce the *identical* fused mask,
  because the replay uses the same chunk boundaries over the same prompt
  tokens) and the already-generated prefix is re-fed through the decode
  path as forced tokens (bit-identical KV, no new sampling).  Cost ∝
  tokens to replay.

Resumed streams are token-identical to preemption-free serving for greedy
AND seeded-sampled requests (the tested guarantee): per-request sampling
is counter-based — every draw is a pure function of (request seed,
generated position, logits) — so a replayed position regenerates the same
token and there is no engine-global RNG stream for preemption to shift.

**Shared-block ownership (prefix caching).**  With the pool's prefix
cache enabled, admission consults the cache first: a hit binds the cached
chain's blocks into the request's table under *shared* ownership
(refcounted; copy-on-write — every write lands past the fork point in
private blocks) and prefill resumes at ``cached_rows`` from the entry's
stat-sum / state-row snapshot.  Preemption respects sharing: swap-out
SKIPS shared blocks (the swapped request keeps its reference; only
private blocks move to host), recompute's ``pool.free`` decrefs shared
blocks instead of freeing them, speculative rollback never un-scatters
into a block with other owners (rollback rows live strictly past the
prompt — the pool raises if that invariant is ever violated), and an
abort in any state — including mid-prefill while holding shared blocks,
or while swapped out — releases exactly the references the request holds.

**Cross-engine migration (replica-sharded serving).**  ``MIGRATING`` is
the leg of the PREEMPTED_SWAPPED path a request takes when its host swap
store is in flight between two engines: the source performs a *full*
swap-out (shared prefix blocks are copied out too — physical block ids
are meaningless in another pool), records ``PREEMPTED_SWAPPED →
MIGRATING``, and detaches the entry; the destination adopts the entry in
MIGRATING and its swap-in tick splices the blocks + GLASS slot rows +
recurrent-state rows into its own pool, resuming at RUNNING (decode) or
PREFILLING (a chunk-boundary-aligned mid-prefill handoff whose partial
GLASS stat left-fold rides along and keeps accumulating).  An abort while
MIGRATING drops the host store — by construction it pins nothing on
either device, so both sides are already released.

The swap path also enforces a host-side *store cap*
(:attr:`PreemptionConfig.swap_store_cap_bytes`): when the resident bytes
of all swap stores would exceed it, the oldest swapped request degrades
``PREEMPTED_SWAPPED → PREEMPTED_RECOMPUTE`` — its host copy is dropped
and the replay path (identical by the recompute guarantee above) serves
the resume instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional

from .scheduler import Request


class ReqState(str, Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    RUNNING = "running"
    SPECULATING = "speculating"
    PREEMPTED_SWAPPED = "preempted_swapped"
    PREEMPTED_RECOMPUTE = "preempted_recompute"
    MIGRATING = "migrating"  # host swap store in flight between engines
    FINISHED = "finished"


_LEGAL = {
    ReqState.WAITING: {
        ReqState.PREFILLING,
        ReqState.FINISHED,  # abort before first admission
    },
    ReqState.PREFILLING: {
        ReqState.RUNNING,  # even max_new == 1 passes through RUNNING to finish
        ReqState.PREEMPTED_RECOMPUTE,  # partial prefill is cheaper to redo than to swap
        # migration-only: a chunk-boundary handoff swaps the partial prefill
        # out (KV blocks + state rows; the stat left-fold travels host-side)
        # so the destination engine resumes it without replaying — the cost
        # model's own preemption still always recomputes prefill victims
        ReqState.PREEMPTED_SWAPPED,
        ReqState.FINISHED,  # abort mid-prefill (slot + blocks released first)
    },
    ReqState.RUNNING: {
        ReqState.FINISHED,  # length / eos / stop / abort
        ReqState.SPECULATING,
        ReqState.PREEMPTED_SWAPPED,
        ReqState.PREEMPTED_RECOMPUTE,
    },
    # SPECULATING is a sub-phase of RUNNING: the slot carries unverified
    # draft rows / provisional outputs.  The ONLY legal exit is back to
    # RUNNING (after commit or a full speculation rollback) — preempting,
    # finishing (including EOS/stop/abort), or swapping a mid-speculation
    # request directly would leak speculated KV rows, blocks, and
    # provisional tokens into the resume path, so the engine must roll the
    # speculation back first.  This is the early-finish leak-class guard:
    # a stop-finishing SPECULATING request takes SPECULATING -> RUNNING ->
    # FINISHED, with the rollback releasing its pending drafts in between.
    ReqState.SPECULATING: {ReqState.RUNNING},
    ReqState.PREEMPTED_SWAPPED: {
        ReqState.RUNNING,
        ReqState.MIGRATING,  # host store handed to another engine
        # swap-store cap overflow: the oldest store is dropped and the
        # request degrades to the recompute-replay resume path
        ReqState.PREEMPTED_RECOMPUTE,
        ReqState.FINISHED,  # abort: the host-side swap store is dropped
    },
    ReqState.PREEMPTED_RECOMPUTE: {
        ReqState.PREFILLING,
        ReqState.FINISHED,  # abort: the queued replay is cancelled
    },
    ReqState.MIGRATING: {
        ReqState.RUNNING,  # destination swap-in: decode resumes
        ReqState.PREFILLING,  # destination swap-in: mid-prefill handoff resumes
        ReqState.FINISHED,  # abort in flight: the host store pins nothing
    },
    ReqState.FINISHED: set(),
}


@dataclass
class SpecCheckpoint:
    """Everything needed to roll a request back to its last *accepted*
    token: taken when the request enters SPECULATING, dropped at commit.

    ``rows``/``out_len``/``pending`` snapshot the host-side progress;
    ``ensured`` is the KV-row capacity the speculative round reserved (the
    rollback zeroes ``[rows, ensured)`` and shrinks holdings back to
    ``rows``); ``state_rows`` is the device copy of the recurrent-state
    rows (the pre-draft state carry — None for pure-KV families)."""

    rows: int  # pool lengths[slot] at speculation entry
    ensured: int  # KV rows the round ensured capacity for (rows + k + 1)
    out_len: int  # len(outputs) at speculation entry
    pending: int  # next token to feed at speculation entry
    state_rows: Any = None


@dataclass(eq=False)
class LiveRequest:
    """One request's lifecycle entry: scheduling state + everything needed
    to resume it after preemption (host-side; device state lives in the
    pool / GLASS arenas and is re-bound at each transition).

    ``eq=False``: entries are identity objects (the engine keeps them in
    lists and sets); the default dataclass ``__eq__`` would compare ndarray
    prompts and raise."""

    req: Request
    state: ReqState = ReqState.WAITING
    slot: int = -1  # pool slot while PREFILLING / RUNNING, else -1
    prefill_pos: int = 0  # prompt tokens already prefilled
    # prefix-cache fork point of the CURRENT admission: prompt rows served
    # from shared cached blocks (prefill started at this position, with
    # stat sums / state rows restored from the cache entry's snapshot).
    # Reset at every admission — a recompute re-admission may fork at a
    # different depth than the first pass and still build the identical
    # fused mask (cached snapshots are left-folds of the same chunk sums).
    cached_rows: int = 0
    outputs: List[int] = field(default_factory=list)  # generated token ids
    pending: int = 0  # next token to feed into decode
    replay_left: int = 0  # forced re-feeds outstanding after a recompute resume
    pstats: Any = None  # running-sum GLASS stats while PREFILLING
    glass_rows: Any = None  # saved per-slot GLASS rows while PREEMPTED_SWAPPED
    glass_key: Optional[bytes] = None  # host active-block-list key (block_sparse)
    swap: Any = None  # BlockPool SwappedRequest while PREEMPTED_SWAPPED / MIGRATING
    swap_seq: int = -1  # swap-out order (cap overflow degrades the oldest store)
    admitted_step: int = -1  # latest admission (for prefill ordering)
    first_admitted_step: int = -1  # first admission (admission-latency metric)
    preemptions: int = 0
    # speculative decode: provisional draft tokens currently appended to
    # ``outputs`` (unverified — anything reading outputs as ground truth,
    # e.g. recompute's forced-token replay, must slice them off first) and
    # the rollback checkpoint while SPECULATING
    spec_len: int = 0
    spec_ckpt: Optional[SpecCheckpoint] = None
    # per-request generation policy, resolved against the engine defaults at
    # submit (sp: SamplingParams; gp: GlassParams with every field concrete)
    sp: Any = None
    gp: Any = None
    finish_reason: Optional[str] = None  # length | stop | eos | aborted
    emitted: int = 0  # accepted tokens already reported through step()
    # counter-based PRNG position: the next sampled token's counter.  The
    # engine maintains the invariant rng_pos == len(outputs) whenever the
    # entry is not mid-speculation — provisional draft tokens do NOT
    # advance it until the target tier accepts them, and rollback rewinds
    # it with outputs (the state-churn determinism tests assert this
    # counter against an undisturbed engine's).
    rng_pos: int = 0

    @property
    def uid(self) -> int:
        return self.req.uid


class Lifecycle:
    """Registry of live entries + the legal-transition checker.

    ``counts[(from, to)]`` tallies every transition taken — the engine's
    preemption telemetry and the tests' flow assertions both read it.
    """

    def __init__(self):
        self.entries: Dict[int, LiveRequest] = {}
        self.counts: Dict[tuple, int] = {}

    def add(self, req: Request) -> LiveRequest:
        if req.uid in self.entries and self.entries[req.uid].state is not ReqState.FINISHED:
            raise ValueError(f"request {req.uid} is already live")
        e = LiveRequest(req=req)
        self.entries[req.uid] = e
        return e

    def to(self, e: LiveRequest, new: ReqState) -> None:
        if new not in _LEGAL[e.state]:
            raise ValueError(f"illegal transition {e.state.value} -> {new.value} (uid={e.uid})")
        self.counts[(e.state.value, new.value)] = self.counts.get((e.state.value, new.value), 0) + 1
        e.state = new
        if new is ReqState.FINISHED and self.entries.get(e.uid) is e:
            # finished entries are dead weight (prompt + full token list):
            # prune so a long-lived engine stays O(in-flight), not O(served)
            del self.entries[e.uid]

    def detach(self, e: LiveRequest) -> None:
        """Remove a MIGRATING entry from this lifecycle: its host store (and
        with it the request) now belongs to another engine's lifecycle.  The
        PREEMPTED_SWAPPED → MIGRATING transition must already be recorded —
        detaching any other state would bypass the legality checker."""
        if e.state is not ReqState.MIGRATING:
            raise ValueError(f"detach of non-migrating entry (uid={e.uid}, {e.state.value})")
        if self.entries.get(e.uid) is e:
            del self.entries[e.uid]

    def adopt(self, e: LiveRequest) -> None:
        """Install a MIGRATING entry detached from another engine's
        lifecycle.  The entry arrives mid-machine (its transition history
        lives with the source), so adoption only checks liveness and state —
        every later move goes through :meth:`to` as usual."""
        if e.state is not ReqState.MIGRATING:
            raise ValueError(f"adopt of non-migrating entry (uid={e.uid}, {e.state.value})")
        if e.uid in self.entries and self.entries[e.uid].state is not ReqState.FINISHED:
            raise ValueError(f"request {e.uid} is already live")
        self.entries[e.uid] = e

    def in_state(self, *states: ReqState) -> List[LiveRequest]:
        return [e for e in self.entries.values() if e.state in states]

    def by_slot(self, slot: int) -> LiveRequest:
        for e in self.entries.values():
            if e.slot == slot and e.state in (
                ReqState.PREFILLING, ReqState.RUNNING, ReqState.SPECULATING
            ):
                return e
        raise KeyError(f"no live entry bound to slot {slot}")

    def preempted(self, *, kind: Optional[str] = None) -> int:
        """Total preemption transitions taken (optionally one kind).  The
        swap-cap degrade (PREEMPTED_SWAPPED → PREEMPTED_RECOMPUTE) is not a
        new preemption event — that victim was already counted at swap-out
        — so it is excluded here (the engine tallies it separately)."""
        total = 0
        for (src, dst), n in self.counts.items():
            if dst == ReqState.PREEMPTED_SWAPPED.value and kind in (None, "swap"):
                total += n
            elif (dst == ReqState.PREEMPTED_RECOMPUTE.value
                  and src != ReqState.PREEMPTED_SWAPPED.value
                  and kind in (None, "recompute")):
                total += n
        return total


@dataclass(frozen=True)
class PreemptionConfig:
    """Knobs for the swap-vs-recompute decision and the allocation reserve.

    ``mode="auto"`` picks per victim by comparing
    ``blocks_held * swap_cost_per_block`` (bytes copied out and back)
    against ``tokens_to_replay * recompute_cost_per_token`` (prompt +
    generated prefix re-run through prefill/forced decode).  The defaults
    make swap win for long contexts with little generated text and
    recompute win for short contexts — the vLLM-style tradeoff.
    ``watermark_blocks`` is the free-block reserve that *admissions* must
    leave untouched (running requests may grow into it), so a fresh
    admission cannot instantly force a preemption.

    ``swap_store_cap_bytes`` bounds the host-side residency of swap
    stores: when a new swap-out would push the engine's total resident
    store bytes past the cap, the OLDEST swapped request degrades to
    recompute (its store is dropped, it re-queues for the replay resume —
    streams stay identical by the recompute guarantee).  ``None`` (the
    default) leaves the store unbounded.
    """

    mode: str = "auto"  # auto | swap | recompute
    swap_cost_per_block: float = 2.0
    recompute_cost_per_token: float = 1.0
    watermark_blocks: int = 1
    swap_store_cap_bytes: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("auto", "swap", "recompute"):
            raise ValueError(f"unknown preemption mode {self.mode!r}")
        if self.swap_store_cap_bytes is not None and self.swap_store_cap_bytes < 0:
            raise ValueError(
                f"swap_store_cap_bytes must be >= 0 or None, got {self.swap_store_cap_bytes}"
            )


def preemption_kind(cfg: PreemptionConfig, blocks_held: int, tokens_to_replay: int) -> str:
    """Cost-model decision for one victim: ``"swap"`` or ``"recompute"``."""
    if cfg.mode != "auto":
        return cfg.mode
    swap_cost = blocks_held * cfg.swap_cost_per_block
    recompute_cost = tokens_to_replay * cfg.recompute_cost_per_token
    return "swap" if swap_cost < recompute_cost else "recompute"
