"""KV-cache pools for continuous batching: slot arena and paged block table.

``KVPool`` (PR 1) owns ONE fixed cache arena allocated via
``model.init_cache`` with batch = ``max_slots`` and sequence capacity =
``max_len``: every request pays for the worst-case context.  It is kept as
the reference memory subsystem for ``ContinuousEngine``.

``BlockPool`` is the paged refactor used by ``PagedEngine``: KV rows live in
fixed-size *blocks* shared by all requests, each request holds a *block
table* (list of block ids in logical order), and a request's footprint is
``ceil(rows / block_size)`` blocks instead of ``max_len`` rows.  Recurrent
state (rwkv/mamba/conv) has no sequence axis and stays a per-slot arena.

Correctness across requests relies on the same two invariants as the slot
arena:

  * every attention read is masked by the request's own length (``kv_len``
    in ``causal_window_mask``), so stale KV beyond a request's frontier —
    from a block's previous owner or from the zero-init — is never attended;
  * recurrent state is fully overwritten during (chunked) prefill and
    zeroed on eviction, so state families cannot leak either.

Cache-leaf layout is *discovered*, not hard-coded: diffing ``eval_shape`` of
``init_cache`` for batch 1 vs 2 finds the slot axis of every leaf, and
diffing ``max_len`` vs ``2 * max_len`` finds the sequence axis of the leaves
that have one (the *paged* leaves).  That keeps both pools family-agnostic
(dense KV stacks, rwkv state tuples, hybrid mamba+KV mixtures) and robust to
new cache layouts.

Block id 0 is a reserved *trash block*: inactive rows of the fixed-size
decode batch point their (masked, never-read) writes at it, so the jitted
decode step needs no per-row branching.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def slot_axes(model, max_len: int):
    """Pytree (matching the cache structure) of each leaf's slot-axis index."""
    c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
    c2 = jax.eval_shape(lambda: model.init_cache(2, max_len))

    def ax(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")

    return jax.tree.map(ax, c1, c2)


def write_slot_leaf(dst: jax.Array, src: jax.Array, axis: int, slot) -> jax.Array:
    """Write ``src`` (slot-axis size 1, other axes <= dst's) at ``slot``."""
    starts = [jnp.int32(0)] * dst.ndim
    starts[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)


def clear_slot_leaf(dst: jax.Array, axis: int, slot) -> jax.Array:
    """Zero the size-1 row of ``dst`` at ``slot`` along ``axis``."""
    shape = list(dst.shape)
    shape[axis] = 1
    return write_slot_leaf(dst, jnp.zeros(shape, dst.dtype), axis, slot)


class KVPool:
    """Fixed ``max_slots`` x ``max_len`` cache arena with per-slot lengths."""

    def __init__(self, model, max_slots: int, max_len: int):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len)
        self.axes = slot_axes(model, max_len)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self._free: List[int] = list(range(max_slots))[::-1]  # pop() -> slot 0 first

        def write(arena, req_cache, slot):
            return jax.tree.map(
                lambda dst, src, a: write_slot_leaf(dst, src, a, slot),
                arena, req_cache, self.axes,
            )

        def clear(arena, slot):
            return jax.tree.map(
                lambda dst, a: clear_slot_leaf(dst, a, slot), arena, self.axes
            )

        # jitted so repeated admissions/evictions with the same request shape
        # reuse the compiled scatter; the old arena is dead after each call,
        # so donate it and update in place instead of copying the full cache
        self._write = jax.jit(write, donate_argnums=(0,))
        self._clear = jax.jit(clear, donate_argnums=(0,))

    # -- slot lifecycle -----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def write_prefill(self, slot: int, req_cache, length: int) -> None:
        """Insert a single-request prefill cache (batch 1) into ``slot``."""
        self.cache = self._write(self.cache, req_cache, jnp.int32(slot))
        self.lengths[slot] = length
        self.active[slot] = True

    def free(self, slot: int) -> None:
        """Evict: zero the slot's row (hygiene; masking is the correctness
        mechanism) and return it to the free list."""
        self.cache = self._clear(self.cache, jnp.int32(slot))
        self.lengths[slot] = 0
        self.active[slot] = False
        self._free.append(slot)


# ---------------------------------------------------------------------------
# Paged block table
# ---------------------------------------------------------------------------


def pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to [1, cap].  Shared by the
    engine's gather-width bucketing and the pool's swap padding so jitted
    variants stay O(log cap)."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


@dataclass
class SwappedRequest:
    """Host-side store of one preempted-by-swap request's device state.

    ``host`` mirrors the cache pytree: paged leaves hold the request's
    gathered PRIVATE blocks (padded to a power of two with trash-block
    copies so the gather/scatter jits compile O(log nb) variants), state
    leaves hold the slot's row.  Swap-in writes it back bit-identical into
    freshly allocated blocks / a freshly allocated slot.

    ``kept`` lists the shared (prefix-cache-registered) blocks the request
    did NOT copy out: it keeps its ownership reference on them across the
    swap — they stay on device, immutable, pinned against eviction — and
    swap-in splices the same physical ids back into the rebuilt table.
    """

    host: Any
    n_blocks: int  # private live blocks to re-allocate (excludes kept + padding)
    n_padded: int  # gather width actually stored
    length: int  # pool lengths[slot] at swap-out
    nbytes: int  # live bytes moved out (telemetry)
    kept: List[Tuple[int, int]] = field(default_factory=list)  # (table idx, block id)


@dataclass
class SwappedWire:
    """Pool-independent serialization of a :class:`SwappedRequest` — the
    cross-engine migration payload.

    ``leaves`` are host numpy arrays in ``jax.tree.leaves`` order of the
    cache pytree; the destination pool re-hangs them on its OWN tree
    structure (:meth:`BlockPool.adopt_wire`), so nothing in the wire
    references source-pool state.  Only a *full* swap-out is exportable:
    a ``kept`` list pins physical block ids in the source allocator, and
    physical ids are meaningless in another pool.

    ``block_size`` / ``nb_max`` stamp the layout the leaves were gathered
    under — adoption validates them so a wire can never splice into a pool
    with a different block geometry (the gather/scatter jit shapes, block
    table width, and row addressing all key off these two).  In-process
    the wire is a plain dataclass of numpy arrays + ints; a true
    multi-process transport would pickle/serialize exactly these fields.
    """

    leaves: List[Any]  # host numpy arrays, jax.tree.leaves(cache) order
    n_blocks: int
    n_padded: int
    length: int
    nbytes: int
    block_size: int
    nb_max: int


class BlockAllocator:
    """Refcounted free-list allocator over block ids ``1..num_blocks-1``
    (0 = trash).

    Host-side and strict: double-frees, foreign ids, and freeing a block
    that still has owners raise instead of silently corrupting the table
    (a stale free would hand one block to two live requests — the exact
    cross-request KV leak the pool must prevent).

    Ownership model (prefix caching): ``alloc`` hands out blocks with
    refcount 1; ``incref`` adds an owner when a new request shares an
    already-written prefix block (copy-on-write tables never write shared
    blocks, so sharing is read-only by construction); ``decref`` drops an
    owner and returns the ids that reached refcount 0 — those stay *live*
    (allocated but unowned, e.g. retained by the prefix-cache index) until
    :meth:`free` returns them to the free stack.  ``free`` only accepts
    refcount-0 live ids, so a shared block can never be reclaimed out from
    under a reader.
    """

    TRASH = 0

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 usable + trash), got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(1, num_blocks))[::-1]  # pop() -> block 1 first
        self._live: set = set()
        self._ref: Dict[int, int] = {}  # live id -> owner count (0 = retained)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._live)

    def refcount(self, block: int) -> int:
        """Owner count of a live id (0 for retained-but-unowned ids);
        raises on free/foreign ids."""
        if block not in self._live:
            raise ValueError(f"refcount of non-live block id {block}")
        return self._ref[block]

    def alloc(self, n: int) -> Optional[List[int]]:
        """n blocks at refcount 1 each, or None (allocation is all-or-nothing)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._live.update(out)
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, blocks: List[int]) -> None:
        """Add one owner per id (prefix sharing).  Ids must be live; a
        retained refcount-0 id is resurrected to owned here."""
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"incref of non-live block id {b}")
        for b in blocks:
            self._ref[b] += 1

    def decref(self, blocks: List[int]) -> List[int]:
        """Drop one owner per id.  Returns (in input order) the ids whose
        refcount reached 0 — they STAY live; the caller either retains
        them (prefix-cache index) or hands them to :meth:`free`.  A decref
        past zero is the double-free class and raises."""
        zeroed: List[int] = []
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"double-free or foreign block id {b}")
        # duplicate ids in ONE call are fine for decref (a request may
        # legitimately hold several references) — but each occurrence must
        # be backed by an owner
        counts: Dict[int, int] = {}
        for b in blocks:
            counts[b] = counts.get(b, 0) + 1
        for b, c in counts.items():
            if self._ref[b] < c:
                raise ValueError(
                    f"decref of block id {b} x{c} with only {self._ref[b]} owners"
                )
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                zeroed.append(b)
        return zeroed

    def free(self, blocks: List[int]) -> None:
        """Return fully-released (refcount-0) live ids to the free stack.
        Freeing an owned block raises — callers release ownership through
        :meth:`decref` first (legacy exclusive-owner paths do both in one
        pool-level release)."""
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate block ids in free: {blocks}")
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"double-free or foreign block id {b}")
            if self._ref[b] != 0:
                raise ValueError(
                    f"free of block id {b} with refcount {self._ref[b]} > 0"
                )
        for b in blocks:
            self._live.remove(b)
            del self._ref[b]
            self._free.append(b)

    def release(self, blocks: List[int]) -> List[int]:
        """Drop one owner per id and return the fully-released ones to the
        free stack in one step (the exclusive-owner fast path).  Returns
        the freed ids; callers that retain refcount-0 blocks (the prefix
        cache) use :meth:`decref` / :meth:`free` separately instead."""
        zeroed = self.decref(blocks)
        self.free(zeroed)
        return zeroed


# ---------------------------------------------------------------------------
# Content-addressed prefix cache
# ---------------------------------------------------------------------------


@dataclass
class PrefixEntry:
    """One cached full KV block in a (token-ids, model-config) prefix chain.

    ``key`` is the chain hash up to and including this block's tokens
    (``parent`` is the previous block's key, or the namespace root);
    ``block`` is the physical block id whose rows hold these tokens' KV.
    ``tokens`` is kept for exact verification — a hash collision must
    degrade to a miss, never to serving another prompt's KV.

    ``resumable`` entries end on a boundary that was both a block edge and
    a prefill-chunk edge of the writer, and carry the writer's running
    GLASS stat sums (``pstats``, the PR-2 left-fold at exactly this many
    prompt tokens) plus the recurrent-state rows (``state_rows``, rwkv6 /
    hybrid) at the same position — everything a cache hit needs to resume
    ``prefill_chunk`` bit-identically to an uncached prefill.
    """

    key: int
    parent: int
    depth: int  # blocks from the chain root, 1-based
    block: int  # physical block id, or -1 (pure-state family: no KV blocks)
    tokens: tuple
    resumable: bool = False
    pstats: Any = None
    state_rows: Any = None
    tick: int = 0  # LRU stamp


class PrefixCache:
    """Hash index over full KV blocks keyed by (token-ids, model config)
    prefix chains, with LRU eviction of refcount-0 entries.

    The cache never owns device memory itself: entries point at allocator
    blocks whose owner counts are managed by :class:`BlockPool` — a block
    referenced only by the index sits at refcount 0 (retained, evictable),
    and a hit resurrects it via ``incref``.  Eviction walks chain LEAVES
    first (an interior block may still anchor a deeper cached prefix) and
    only frees blocks nobody owns.
    """

    def __init__(self, block_size: int, namespace: str = "",
                 max_blockless: int = 256):
        self.block_size = block_size
        self.entries: Dict[int, PrefixEntry] = {}
        self.by_block: Dict[int, int] = {}  # physical block id -> entry key
        self._children: Dict[int, int] = {}  # entry key -> child-entry count
        self._root = hash(("glass-prefix-cache", namespace))
        self._tick = 0
        # pure-state families (rwkv6) cache block-less entries whose resume
        # snapshots retain full device state-row copies; with no paged
        # blocks there is no allocation pressure to evict them, so a hard
        # entry cap (LRU leaf-first) bounds that memory instead
        self.max_blockless = max_blockless
        self.n_blockless = 0  # incremental count of block-less entries
        # |{registered blocks at refcount 0}| — maintained incrementally by
        # BlockPool (retention on release-to-zero, resurrection on prefix
        # sharing) and by :meth:`evict`, so the per-tick
        # ``n_reclaimable_blocks`` reads are O(1) instead of an index scan
        self.retained = 0
        # telemetry (the serve bench's shared_prefix scenario reads these)
        self.hits = 0
        self.misses = 0
        self.tokens_saved = 0
        self.inserts = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def _child_key(self, parent: int, toks: tuple) -> int:
        return hash((parent, toks))

    def _bump(self, entry: PrefixEntry) -> None:
        self._tick += 1
        entry.tick = self._tick

    def lookup(self, prompt, align: int) -> tuple:
        """Longest resumable cached prefix of ``prompt``.

        Returns ``(fork_rows, entries)``: the chain of :class:`PrefixEntry`
        covering ``fork_rows`` prompt tokens, where ``fork_rows`` is the
        deepest chain position that (a) carries a resume snapshot
        (``resumable``), (b) is a multiple of ``align`` (the engine's
        ``chunk_tokens`` — resumed chunk boundaries must coincide with the
        cold run's, or the stat left-fold would associate differently),
        and (c) leaves at least one prompt token to prefill (the final
        chunk must produce the first-token logits).  ``(0, [])`` on miss.
        """
        bs = self.block_size
        key = self._root
        chain: List[PrefixEntry] = []
        best = 0
        for d in range(1, len(prompt) // bs + 1):
            toks = tuple(int(t) for t in prompt[(d - 1) * bs : d * bs])
            key = self._child_key(key, toks)
            e = self.entries.get(key)
            if e is None or e.tokens != toks:
                break
            chain.append(e)
            rows = d * bs
            if e.resumable and rows % align == 0 and rows <= len(prompt) - 1:
                best = d
        if not best:
            return 0, []
        hit = chain[:best]
        for e in hit:  # protect the whole path from eviction races
            self._bump(e)
        return best * bs, hit

    def peek(self, prompt, align: int) -> int:
        """Read-only :meth:`lookup`: the longest resumable cached prefix
        length (rows) of ``prompt``, with NO side effects — no LRU bump, no
        eviction pinning, no hit/miss accounting.  Built for the cluster
        dispatcher, which probes every replica's cache per admission to
        score prefix affinity: a probe is not a use, so it must not
        reorder eviction or skew hit-rate telemetry (N-1 of the N probes
        route nowhere)."""
        bs = self.block_size
        key = self._root
        best = 0
        for d in range(1, len(prompt) // bs + 1):
            toks = tuple(int(t) for t in prompt[(d - 1) * bs : d * bs])
            key = self._child_key(key, toks)
            e = self.entries.get(key)
            if e is None or e.tokens != toks:
                break
            rows = d * bs
            if e.resumable and rows % align == 0 and rows <= len(prompt) - 1:
                best = rows
        return best

    def insert_chain(
        self,
        prompt,
        upto: int,
        blocks,
        *,
        resumable: bool = False,
        pstats=None,
        state_rows=None,
        allocator: Optional[BlockAllocator] = None,
    ) -> int:
        """Register the full blocks covering ``prompt[:upto]`` rows, block
        ``d``'s rows living in physical block ``blocks[d-1]``.

        Chains are extended, never overwritten: a key that already exists
        keeps its original physical block when that block is still OWNED
        (the concurrent-writer dedup — the second writer simply keeps its
        private copy unregistered).  A retained (refcount-0) dedup target
        is instead ADOPTED: the entry is re-pointed at the writer's
        identical copy and the orphaned block freed.  Without adoption, a
        writer re-populating a partially-evicted chain would hang its
        owned deeper entries under unowned ancestors, breaking the
        invariant that every owner of a cached block also owns its chain
        ancestors — and with it the accounting that retained blocks are
        leaf-evictable on demand.  Adoption converts one retained block
        into one free block, so supply is unchanged and exact.

        When ``resumable``, the terminal entry (at exactly ``upto`` rows,
        which must be block-aligned) is stamped with the resume snapshot —
        including an existing entry that lacked one (snapshots are
        physical-block-independent, so upgrading a dedup'd entry is
        sound).  Returns the number of NEW entries created."""
        bs = self.block_size
        full = upto // bs
        parent = self._root
        created = 0
        for d in range(1, full + 1):
            toks = tuple(int(t) for t in prompt[(d - 1) * bs : d * bs])
            key = self._child_key(parent, toks)
            e = self.entries.get(key)
            if e is not None and e.tokens == toks and allocator is not None:
                b = int(blocks[d - 1]) if blocks is not None else -1
                if (e.block >= 0 and b >= 0 and b != e.block
                        and b not in self.by_block
                        and allocator.refcount(e.block) == 0):
                    allocator.free([e.block])
                    del self.by_block[e.block]
                    self.retained -= 1
                    e.block = b
                    self.by_block[b] = key
            if e is None:
                b = int(blocks[d - 1]) if blocks is not None else -1
                if b >= 0 and b in self.by_block:
                    # one physical block cannot anchor two entries — can
                    # only happen on a foreign block id; fail loudly
                    raise ValueError(f"block {b} already registered")
                e = PrefixEntry(key=key, parent=parent, depth=d, block=b, tokens=toks)
                self.entries[key] = e
                if b >= 0:
                    self.by_block[b] = key
                else:
                    self.n_blockless += 1
                if parent != self._root:
                    self._children[parent] = self._children.get(parent, 0) + 1
                created += 1
                self.inserts += 1
            elif e.tokens != toks:  # hash collision: leave the chain alone
                break
            if resumable and d * bs == upto and not e.resumable:
                e.resumable = True
                e.pstats = pstats
                e.state_rows = state_rows
            self._bump(e)
            parent = key
        self._enforce_blockless_cap()
        return created

    def _enforce_blockless_cap(self) -> None:
        """LRU-evict block-less leaves past ``max_blockless`` entries.
        Block-less entries have no owners by construction, so every leaf
        is immediately evictable; evicting a leaf may expose its parent,
        so repeated leaf eviction can always reach the cap.  The chain
        just inserted is MRU — an over-cap insert trims older chains (or,
        if it alone exceeds the cap, its own deepest tail) rather than
        growing without bound."""
        while self.n_blockless > self.max_blockless:
            cands = [
                e for e in self.entries.values()
                if e.block < 0 and not self._children.get(e.key, 0)
            ]
            if not cands:
                break
            self.evict(None, min(cands, key=lambda e: e.tick))

    def evictable(self, allocator: Optional[BlockAllocator]) -> List[PrefixEntry]:
        """Refcount-0 chain leaves, LRU-first (block-less pure-state
        entries have no owners by construction)."""
        out = [
            e for e in self.entries.values()
            if self._children.get(e.key, 0) == 0
            and (e.block < 0 or allocator.refcount(e.block) == 0)
        ]
        out.sort(key=lambda e: e.tick)
        return out

    def evict_for(self, allocator: Optional[BlockAllocator], n_blocks: int) -> int:
        """Free up to ``n_blocks`` blocks by evicting LRU refcount-0
        leaves (re-scanning after each eviction — freeing a leaf may
        expose its parent).  Returns the number of blocks freed."""
        freed = 0
        while freed < n_blocks:
            cands = [e for e in self.evictable(allocator) if e.block >= 0]
            if not cands:
                break
            self.evict(allocator, cands[0])
            freed += 1
        return freed

    def evict(self, allocator: Optional[BlockAllocator], entry: PrefixEntry) -> None:
        """Drop one refcount-0 leaf entry and free its block (if any)."""
        if self._children.get(entry.key, 0):
            raise ValueError(f"evicting interior cache entry at depth {entry.depth}")
        if entry.block >= 0:
            allocator.free([entry.block])  # raises unless refcount 0, i.e. retained
            del self.by_block[entry.block]
            self.retained -= 1
        else:
            self.n_blockless -= 1
        del self.entries[entry.key]
        if entry.parent != self._root:
            self._children[entry.parent] -= 1
            if not self._children[entry.parent]:
                del self._children[entry.parent]
        self.evictions += 1


def paged_layout(model, max_len: int):
    """Discover (slot_axis, seq_axis-or-None) per cache leaf via eval_shape.

    Returns (axes, seq_axes, paged): pytrees matching the cache structure
    with int leaves — ``paged`` uses 1/0 (python bools/ints keep tree.map
    happy where None would not)."""
    axes = slot_axes(model, max_len)
    c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
    c2 = jax.eval_shape(lambda: model.init_cache(1, 2 * max_len))

    def seq_ax(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return -1  # state leaf: no sequence axis

    seq_axes = jax.tree.map(seq_ax, c1, c2)
    paged = jax.tree.map(lambda s: int(s >= 0), seq_axes)
    return axes, seq_axes, paged


class BlockPool:
    """Paged KV block table + per-slot state arena.

    Paged leaves replace their ``(batch, seq)`` axis pair with
    ``(num_blocks, block_size)``; a request's KV rows live at logical
    position ``t`` in block ``table[t // block_size]``, offset
    ``t % block_size``.  State leaves (rwkv/mamba) keep a ``max_slots``
    slot arena exactly like :class:`KVPool`.

    The pool only manages memory: block tables, the free lists, and the
    arena buffers.  All device writes happen inside the engine's jitted
    chunk-prefill / decode calls (which receive the arena donated), so the
    pool never dispatches per-token work.
    """

    def __init__(
        self,
        model,
        max_slots: int,
        max_len: int,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        watermark: int = 0,
        prefix_cache: bool = False,
        cache_namespace: str = "",
        cache_blockless_cap: int = 256,
    ):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.block_size = block_size
        self.watermark = watermark  # free blocks ADMISSIONS must leave untouched
        self.nb_max = -(-max_len // block_size)  # blocks per request, worst case
        if num_blocks is None:
            num_blocks = max_slots * self.nb_max + 1  # worst case + trash
        self.num_blocks = num_blocks
        self.axes, self.seq_axes, self.paged = paged_layout(model, max_len)
        self.has_paged = any(jax.tree.leaves(self.paged))

        c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))

        def arena_shape(leaf, slot_ax, seq_ax, pg):
            shape = list(leaf.shape)
            if pg:
                if seq_ax != slot_ax + 1:
                    raise ValueError(
                        f"paged leaf needs adjacent (batch, seq) axes, got "
                        f"slot={slot_ax} seq={seq_ax} shape={leaf.shape}"
                    )
                shape[slot_ax : seq_ax + 1] = [num_blocks, block_size]
            else:
                shape[slot_ax] = max_slots
            return jnp.zeros(shape, leaf.dtype)

        self.cache = jax.tree.map(arena_shape, c1, self.axes, self.seq_axes, self.paged)
        self.allocator = BlockAllocator(num_blocks) if self.has_paged else None
        # content-addressed prefix cache (opt-in).  Paged families share
        # physical KV blocks; pure-state families (rwkv6) cache block-less
        # chain entries whose resume snapshots carry the state rows.  The
        # namespace folds the model config into every chain key so one
        # process serving two models can never cross-hit.
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(block_size, cache_namespace, cache_blockless_cap)
            if prefix_cache else None
        )
        self.block_table = np.zeros((max_slots, self.nb_max), np.int32)  # 0 = trash
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self._free_slots: List[int] = list(range(max_slots))[::-1]
        self._held: Dict[int, List[int]] = {}
        self.peak_blocks = 0

        def clear_state(arena, slot):
            def one(a, ax, pg):
                return a if pg else clear_slot_leaf(a, ax, slot)

            return jax.tree.map(one, arena, self.axes, self.paged)

        # eviction hygiene for state rows; paged blocks need no zeroing
        # (kv_len masking is the correctness mechanism for stale rows)
        self._clear_state = jax.jit(clear_state, donate_argnums=(0,))

        def swap_gather(arena, blocks, slot):
            # paged leaves: the request's blocks; state leaves: the slot row
            def one(a, ax, pg):
                if pg:
                    return jnp.take(a, blocks, axis=ax)
                return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            return jax.tree.map(one, arena, self.axes, self.paged)

        def swap_scatter(arena, host, blocks, slot):
            # padding entries in ``blocks`` are TRASH duplicates: their rows
            # carry gathered trash content back into the trash block — no-ops
            def one(a, h, ax, pg):
                if pg:
                    idx = (slice(None),) * ax + (blocks,)
                    return a.at[idx].set(h.astype(a.dtype))
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, h.astype(a.dtype), starts)

            return jax.tree.map(one, arena, host, self.axes, self.paged)

        self._swap_gather = jax.jit(swap_gather)
        self._swap_scatter = jax.jit(swap_scatter, donate_argnums=(0,))

        def state_save(arena, slot):
            # state leaves: the slot's row; paged leaves: an empty slice so
            # the pytree structure round-trips through save/restore
            def one(a, ax, pg):
                if pg:
                    return jax.lax.slice_in_dim(a, 0, 0, axis=0)
                return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            return jax.tree.map(one, arena, self.axes, self.paged)

        def state_restore(arena, rows, slot):
            def one(a, r, ax, pg):
                if pg:
                    return a
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, r.astype(a.dtype), starts)

            return jax.tree.map(one, arena, rows, self.axes, self.paged)

        def zero_rows(arena, pages, offs):
            # un-scatter: zero the addressed (page, offset) KV rows; state
            # leaves pass through (they roll back via the state checkpoint)
            def one(a, ax, pg):
                if not pg:
                    return a
                idx = (slice(None),) * ax + (pages, offs)
                return a.at[idx].set(jnp.zeros((), a.dtype))

            return jax.tree.map(one, arena, self.axes, self.paged)

        self._state_save = jax.jit(state_save)
        self._state_restore = jax.jit(state_restore, donate_argnums=(0,))
        self._zero_rows = jax.jit(zero_rows, donate_argnums=(0,))

    # -- accounting ---------------------------------------------------------

    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def n_free_blocks(self) -> int:
        return self.allocator.n_free if self.allocator else 0

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.n_live if self.allocator else 0

    @property
    def n_reclaimable_blocks(self) -> int:
        """Cache-retained blocks at refcount 0 — the slack beyond the free
        stack that :meth:`_alloc_blocks` can reclaim by eviction.  Every
        owner of a cached block also owns its chain ancestors (hit binding
        increfs whole prefixes; re-registration ADOPTS retained dedup
        targets), so a refcount-0 entry's subtree is normally all
        refcount 0 and leaf-first eviction drains exactly this many
        blocks.  One transient exception: two writers racing the same
        chain can leave a later writer's owned entry under an earlier
        writer's since-released ancestors — those retained blocks are not
        evictable until the deeper owner releases, so callers must treat
        a failed allocation after a passing fit check as recoverable
        (preempt or degrade), never as an invariant violation.  O(1): the
        count is maintained incrementally (retention in
        :meth:`_release_blocks`, resurrection in :meth:`admit_prefix`,
        adoption in :meth:`PrefixCache.insert_chain`, eviction in
        :meth:`PrefixCache.evict`) because admission/growth checks read
        it several times per tick."""
        if self.prefix_cache is None or self.allocator is None:
            return 0
        return self.prefix_cache.retained

    @property
    def n_available_blocks(self) -> int:
        """Free stack + reclaimable cache slack: the supply admission,
        growth, and swap-in checks must measure against (all three
        allocate through the evicting :meth:`_alloc_blocks`)."""
        return self.n_free_blocks + self.n_reclaimable_blocks

    def blocks_needed(self, rows: int) -> int:
        return -(-rows // self.block_size) if self.has_paged else 0

    def fits(self, rows: int) -> bool:
        return (not self.has_paged) or self.blocks_needed(rows) <= self.n_available_blocks

    def fits_admission(self, rows: int, reserved: int = 0) -> bool:
        """Admission-time fit: must leave the watermark reserve free (growth
        of already-running requests may consume it; fresh admissions may
        not, so admitting cannot instantly force a preemption).  On an IDLE
        pool the watermark is waived — there is nobody to preempt, and
        holding the reserve would permanently starve any request whose
        first chunk needs it (liveness beats headroom).  ``reserved`` adds
        further off-book claims — e.g. blocks owed to swapped-out requests
        awaiting swap-in."""
        if not self.has_paged:
            return True
        wm = self.watermark if self.active.any() else 0
        return self.blocks_needed(rows) + wm + reserved <= self.n_available_blocks

    def held_blocks(self, slot: int) -> int:
        return len(self._held.get(slot, ()))

    @property
    def has_state(self) -> bool:
        """True when the cache has recurrent-state leaves (rwkv/mamba/conv)
        that live in the per-slot arena rather than the paged blocks."""
        return not all(jax.tree.leaves(self.paged))

    # -- speculative rollback -----------------------------------------------

    def save_state_rows(self, slot: int):
        """Device checkpoint of ``slot``'s recurrent-state rows (the
        pre-draft state carry).  Returns None for pure-KV families."""
        if not self.has_state:
            return None
        return self._state_save(self.cache, jnp.int32(slot))

    def restore_state_rows(self, slot: int, rows) -> None:
        """Write back rows captured by :meth:`save_state_rows`."""
        if rows is None:
            return
        self.cache = self._state_restore(self.cache, rows, jnp.int32(slot))

    def rollback_rows(self, slot: int, start: int, end: int) -> None:
        """Un-scatter speculated KV rows: zero logical rows ``[start, end)``
        of ``slot`` through its block table.  The addressed pages must still
        be held by the slot (zero before :meth:`shrink_to`, not after).  The
        row list is padded to a power of two with trash-block redirects
        (block 0, offset 0) so the jitted scatter compiles O(log) variants —
        zeroing the trash block is harmless by definition."""
        if not self.has_paged or end <= start:
            return
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        bs = self.block_size
        pages = [int(self.block_table[slot, r // bs]) for r in range(start, end)]
        offs = [r % bs for r in range(start, end)]
        # copy-on-write invariant: speculative rows live strictly past the
        # prompt, and shared prefix blocks are never written after
        # registration — un-scattering one would corrupt every reader, so
        # a shared/cached page here is a bookkeeping bug, not a request
        for pg in set(pages):
            if pg == BlockAllocator.TRASH:
                continue
            if self.allocator.refcount(pg) > 1 or (
                self.prefix_cache is not None and pg in self.prefix_cache.by_block
            ):
                raise ValueError(
                    f"rollback would un-scatter shared/cached block {pg}"
                )
        p = pow2_bucket(len(pages), max(1, self.nb_max * bs))
        pages += [BlockAllocator.TRASH] * (p - len(pages))
        offs += [0] * (p - len(offs))
        self.cache = self._zero_rows(
            self.cache, jnp.asarray(pages, jnp.int32), jnp.asarray(offs, jnp.int32)
        )

    def shrink_to(self, slot: int, rows: int) -> None:
        """Release blocks allocated past ``rows`` KV rows (speculative
        growth that rejection rolled back).  Blocks are freed in REVERSE
        allocation order so the allocator's free stack returns to exactly
        its pre-speculation state — a never-speculated pool and a
        rolled-back one hand out identical block ids from here on."""
        if not self.has_paged:
            return
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        need = max(self.blocks_needed(rows), 0)
        held = self._held[slot]
        if len(held) <= need:
            return
        extra = held[need:]
        del held[need:]
        self.block_table[slot, need : need + len(extra)] = 0
        self._release_blocks(list(reversed(extra)))

    # -- block ownership (refcounts + prefix-cache retention) ----------------

    def _alloc_blocks(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh blocks, evicting LRU refcount-0 prefix
        cache entries under pressure (retained cache blocks are exactly
        the reclaimable slack — nobody owns them)."""
        got = self.allocator.alloc(n)
        if got is None and self.prefix_cache is not None:
            self.prefix_cache.evict_for(self.allocator, n - self.allocator.n_free)
            got = self.allocator.alloc(n)
        return got

    def _release_blocks(self, blocks: List[int]) -> None:
        """Drop one ownership reference per block.  Fully-released blocks
        return to the free stack UNLESS the prefix cache still indexes
        them — those are retained at refcount 0 (LRU-evictable) so a
        future request with the same prefix can resurrect them."""
        if not blocks:
            return
        zeroed = self.allocator.decref(list(blocks))
        pc = self.prefix_cache
        if pc is None:
            self.allocator.free(zeroed)
            return
        self.allocator.free([b for b in zeroed if b not in pc.by_block])
        for b in zeroed:
            if b in pc.by_block:
                pc.retained += 1  # now reclaimable slack
                pc._bump(pc.entries[pc.by_block[b]])  # fresh in LRU order

    # -- request lifecycle --------------------------------------------------

    def admit(self, rows: int) -> Optional[int]:
        """Allocate a slot + the request's full block need (``rows`` KV
        rows).  Returns the slot, or None if either resource is exhausted."""
        if not self._free_slots:
            return None
        blocks: List[int] = []
        if self.has_paged:
            got = self._alloc_blocks(self.blocks_needed(rows))
            if got is None:
                return None
            blocks = got
        slot = self._free_slots.pop()
        self._held[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, : len(blocks)] = blocks
        self.lengths[slot] = 0
        self.active[slot] = True
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return slot

    def admit_prefix(self, rows: int, entries: List[PrefixEntry]) -> Optional[int]:
        """Admission on a prefix-cache hit: take shared ownership of the
        hit chain's blocks (they enter this request's table read-only —
        the copy-on-write contract: all writes land past the fork point,
        in private blocks) and allocate only the private remainder of the
        ``rows`` footprint.  All-or-nothing like :meth:`admit`."""
        if not self._free_slots:
            return None
        shared = [e.block for e in entries if e.block >= 0]
        blocks: List[int] = []
        if self.has_paged:
            # claim the chain FIRST: the private allocation below may evict
            # refcount-0 cache blocks, and it must never reclaim the ones
            # this admission is resurrecting
            self.prefix_cache.retained -= sum(
                1 for b in shared if self.allocator.refcount(b) == 0
            )
            self.allocator.incref(shared)
            need = self.blocks_needed(rows) - len(shared)
            got = self._alloc_blocks(max(need, 0))
            if got is None:
                self._release_blocks(shared)
                return None
            blocks = shared + got
        slot = self._free_slots.pop()
        self._held[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, : len(blocks)] = blocks
        self.lengths[slot] = 0
        self.active[slot] = True
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return slot

    def lookup_prefix(self, prompt, align: int) -> Tuple[int, List[PrefixEntry]]:
        """Longest resumable cached prefix of ``prompt`` (hit/miss counted
        here — call once per admission).  See :meth:`PrefixCache.lookup`."""
        pc = self.prefix_cache
        if pc is None:
            return 0, []
        fork, entries = pc.lookup(prompt, align)
        if fork:
            pc.hits += 1
            pc.tokens_saved += fork
        else:
            pc.misses += 1
        return fork, entries

    def peek_prefix(self, prompt, align: int) -> int:
        """Side-effect-free probe: rows of the longest resumable cached
        prefix of ``prompt``, mutating neither LRU order nor hit/miss
        telemetry (the cluster dispatcher probes all replicas per
        admission; only the routed-to replica's :meth:`lookup_prefix`
        counts as a use).  See :meth:`PrefixCache.peek`."""
        pc = self.prefix_cache
        if pc is None:
            return 0
        return pc.peek(prompt, align)

    def cancel_prefix_hit(self, fork: int) -> None:
        """Undo one :meth:`lookup_prefix` hit's telemetry: the admission
        could not bind the chain (pinning it consumed the very slack the
        private remainder needed) and degraded to a cold miss."""
        pc = self.prefix_cache
        if pc is None:
            return
        pc.hits -= 1
        pc.misses += 1
        pc.tokens_saved -= fork

    def register_prefix(self, slot: int, prompt, upto: int, *,
                        resumable: bool = False, pstats=None,
                        state_rows=None) -> int:
        """Index the full blocks covering ``prompt[:upto]`` rows written
        by ``slot`` (no-op without a prefix cache).  Call after each
        prefill chunk; ``resumable`` stamps the terminal entry with the
        resume snapshot.  See :meth:`PrefixCache.insert_chain`."""
        pc = self.prefix_cache
        if pc is None or upto < self.block_size:
            return 0
        blocks = self._held[slot] if self.has_paged else None
        return pc.insert_chain(prompt, upto, blocks, resumable=resumable,
                               pstats=pstats, state_rows=state_rows,
                               allocator=self.allocator)

    def ensure_capacity(self, slot: int, rows: int) -> bool:
        """Allocate-on-boundary: grow ``slot`` to cover ``rows`` KV rows,
        allocating only the blocks past its current holding (one block per
        crossed boundary).  All-or-nothing; returns False when the pool
        cannot supply the growth (the caller preempts a victim and
        retries).  Growth deliberately ignores the watermark — the reserve
        exists exactly so running requests can cross a boundary without an
        immediate preemption."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        if not self.has_paged:
            return True
        need = self.blocks_needed(rows)
        held = len(self._held[slot])
        if need <= held:
            return True
        got = self._alloc_blocks(need - held)
        if got is None:
            return False
        self._held[slot].extend(got)
        self.block_table[slot, held : held + len(got)] = got
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return True

    def _pad_blocks(self, blocks: List[int]) -> List[int]:
        """Pad a block list to a power of two with TRASH duplicates so the
        swap gather/scatter jits compile O(log nb_max) shape variants."""
        p = pow2_bucket(max(1, len(blocks)), max(1, self.nb_max))
        return list(blocks) + [BlockAllocator.TRASH] * (p - len(blocks))

    def swap_out(self, slot: int, *, full: bool = False) -> SwappedRequest:
        """Copy the slot's PRIVATE blocks + state rows to host and free
        everything it exclusively owns.  Shared (prefix-cache-registered)
        blocks are SKIPPED: they stay on device with this request's
        ownership reference intact (immutable + pinned, so no bytes move
        and no eviction can reclaim them), and :meth:`swap_in` splices the
        same physical ids back into the rebuilt table.

        ``full=True`` disables the shared-block skip: EVERY held block is
        gathered to host and this slot's references released (shared ones
        decref — the cache retains fully-released registered blocks for
        other requests).  That is the migration form: the resulting store
        pins nothing device-side, so :meth:`export_swap` can carry it to a
        different pool.

        The returned :class:`SwappedRequest` is the request's complete
        device state; :meth:`swap_in` restores it bit-identical."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        blocks = list(self._held.get(slot, ()))
        pc = self.prefix_cache
        keep = (
            {b for b in blocks if b in pc.by_block}
            if pc is not None and not full else set()
        )
        kept = [(i, b) for i, b in enumerate(blocks) if b in keep]
        priv = [b for b in blocks if b not in keep]
        padded = self._pad_blocks(priv)
        host = jax.device_get(
            self._swap_gather(self.cache, jnp.asarray(padded, jnp.int32), jnp.int32(slot))
        )
        # len(priv) may be 0 (every block shared): the gather still moves
        # one padded trash block, but no live bytes — report 0, not 1 block
        live_frac_num, live_frac_den = len(priv), len(padded)
        nbytes = 0
        for h, pg in zip(jax.tree.leaves(host), jax.tree.leaves(self.paged)):
            nbytes += h.nbytes * live_frac_num // live_frac_den if pg else h.nbytes
        sw = SwappedRequest(
            host=host, n_blocks=len(priv), n_padded=len(padded),
            length=int(self.lengths[slot]), nbytes=nbytes, kept=kept,
        )
        # release ONLY the private blocks — the swapped request carries
        # its ownership of the kept (shared) blocks through to swap-in
        self._release_slot(slot, priv)
        return sw

    def export_swap(self, sw: SwappedRequest) -> SwappedWire:
        """Flatten a *full* swap store into the pool-independent
        :class:`SwappedWire` migration payload.  Raises on a store with
        ``kept`` blocks — those are physical ids pinned in THIS pool's
        allocator, meaningless anywhere else (use ``swap_out(slot,
        full=True)`` for a migration-bound swap)."""
        if sw.kept:
            raise ValueError(
                "swap store pins shared device blocks and is not portable — "
                "migration requires a full swap-out (swap_out(slot, full=True))"
            )
        return SwappedWire(
            leaves=[np.asarray(l) for l in jax.tree.leaves(sw.host)],
            n_blocks=sw.n_blocks, n_padded=sw.n_padded,
            length=sw.length, nbytes=sw.nbytes,
            block_size=self.block_size, nb_max=self.nb_max,
        )

    def adopt_wire(self, wire: SwappedWire) -> SwappedRequest:
        """Rebuild a migrated store against THIS pool: re-hang the wire's
        host leaves on this pool's cache tree structure so :meth:`swap_in`
        can splice them (cross-pool splice).  Validates the block geometry
        — the scatter addresses rows as ``block * block_size + offset`` and
        pads tables to ``nb_max``, so a geometry mismatch would land rows
        at the wrong logical positions rather than fail loudly."""
        if wire.block_size != self.block_size or wire.nb_max != self.nb_max:
            raise ValueError(
                f"wire layout (block_size={wire.block_size}, nb_max={wire.nb_max}) "
                f"does not match pool (block_size={self.block_size}, nb_max={self.nb_max})"
            )
        structure = jax.tree.structure(self.cache)
        if structure.num_leaves != len(wire.leaves):
            raise ValueError(
                f"wire carries {len(wire.leaves)} leaves, pool cache has "
                f"{structure.num_leaves} — different model layout"
            )
        host = jax.tree.unflatten(structure, wire.leaves)
        return SwappedRequest(
            host=host, n_blocks=wire.n_blocks, n_padded=wire.n_padded,
            length=wire.length, nbytes=wire.nbytes, kept=[],
        )

    def swap_in(self, sw: SwappedRequest) -> Optional[int]:
        """Restore a swapped request into a fresh slot, re-allocating its
        private blocks and splicing kept shared blocks back at their
        original table positions.  Returns the new slot, or None when
        slots/blocks are unavailable (all-or-nothing, so a failed swap-in
        changes nothing)."""
        if not self._free_slots:
            return None
        priv: List[int] = []
        if self.has_paged and sw.n_blocks:
            got = self._alloc_blocks(sw.n_blocks)
            if got is None:
                return None
            priv = got
        slot = self._free_slots.pop()
        kept_at = dict(sw.kept)
        it = iter(priv)
        blocks = [
            kept_at[i] if i in kept_at else next(it)
            for i in range(sw.n_blocks + len(sw.kept))
        ]
        self._held[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, : len(blocks)] = blocks
        padded = priv + [BlockAllocator.TRASH] * (sw.n_padded - len(priv))
        self.cache = self._swap_scatter(
            self.cache, sw.host, jnp.asarray(padded, jnp.int32), jnp.int32(slot)
        )
        self.lengths[slot] = sw.length
        self.active[slot] = True
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        sw.kept = []  # ownership moved back to the slot's held list
        return slot

    def release_swapped(self, sw: Optional[SwappedRequest]) -> None:
        """Abort of a swapped-out request: drop the ownership references
        it kept on shared device blocks (idempotent)."""
        if sw is None or not sw.kept:
            return
        self._release_blocks([b for _, b in sw.kept])
        sw.kept = []

    def free(self, slot: int) -> None:
        """Evict: release the slot's blocks (shared ones decref — the
        prefix cache retains fully-released registered blocks), zero its
        state rows and table."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        self._release_slot(slot, list(self._held.get(slot, ())))

    def _release_slot(self, slot: int, release: List[int]) -> None:
        self._release_blocks(release)
        self._held.pop(slot, None)
        self.block_table[slot, :] = 0
        self.lengths[slot] = 0
        self.active[slot] = False
        self.cache = self._clear_state(self.cache, jnp.int32(slot))
        self._free_slots.append(slot)
