"""KV-cache pools for continuous batching: slot arena and paged block table.

``KVPool`` (PR 1) owns ONE fixed cache arena allocated via
``model.init_cache`` with batch = ``max_slots`` and sequence capacity =
``max_len``: every request pays for the worst-case context.  It is kept as
the reference memory subsystem for ``ContinuousEngine``.

``BlockPool`` is the paged refactor used by ``PagedEngine``: KV rows live in
fixed-size *blocks* shared by all requests, each request holds a *block
table* (list of block ids in logical order), and a request's footprint is
``ceil(rows / block_size)`` blocks instead of ``max_len`` rows.  Recurrent
state (rwkv/mamba/conv) has no sequence axis and stays a per-slot arena.

Correctness across requests relies on the same two invariants as the slot
arena:

  * every attention read is masked by the request's own length (``kv_len``
    in ``causal_window_mask``), so stale KV beyond a request's frontier —
    from a block's previous owner or from the zero-init — is never attended;
  * recurrent state is fully overwritten during (chunked) prefill and
    zeroed on eviction, so state families cannot leak either.

Cache-leaf layout is *discovered*, not hard-coded: diffing ``eval_shape`` of
``init_cache`` for batch 1 vs 2 finds the slot axis of every leaf, and
diffing ``max_len`` vs ``2 * max_len`` finds the sequence axis of the leaves
that have one (the *paged* leaves).  That keeps both pools family-agnostic
(dense KV stacks, rwkv state tuples, hybrid mamba+KV mixtures) and robust to
new cache layouts.

Block id 0 is a reserved *trash block*: inactive rows of the fixed-size
decode batch point their (masked, never-read) writes at it, so the jitted
decode step needs no per-row branching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def slot_axes(model, max_len: int):
    """Pytree (matching the cache structure) of each leaf's slot-axis index."""
    c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
    c2 = jax.eval_shape(lambda: model.init_cache(2, max_len))

    def ax(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")

    return jax.tree.map(ax, c1, c2)


def write_slot_leaf(dst: jax.Array, src: jax.Array, axis: int, slot) -> jax.Array:
    """Write ``src`` (slot-axis size 1, other axes <= dst's) at ``slot``."""
    starts = [jnp.int32(0)] * dst.ndim
    starts[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)


def clear_slot_leaf(dst: jax.Array, axis: int, slot) -> jax.Array:
    """Zero the size-1 row of ``dst`` at ``slot`` along ``axis``."""
    shape = list(dst.shape)
    shape[axis] = 1
    return write_slot_leaf(dst, jnp.zeros(shape, dst.dtype), axis, slot)


class KVPool:
    """Fixed ``max_slots`` x ``max_len`` cache arena with per-slot lengths."""

    def __init__(self, model, max_slots: int, max_len: int):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len)
        self.axes = slot_axes(model, max_len)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self._free: List[int] = list(range(max_slots))[::-1]  # pop() -> slot 0 first

        def write(arena, req_cache, slot):
            return jax.tree.map(
                lambda dst, src, a: write_slot_leaf(dst, src, a, slot),
                arena, req_cache, self.axes,
            )

        def clear(arena, slot):
            return jax.tree.map(
                lambda dst, a: clear_slot_leaf(dst, a, slot), arena, self.axes
            )

        # jitted so repeated admissions/evictions with the same request shape
        # reuse the compiled scatter; the old arena is dead after each call,
        # so donate it and update in place instead of copying the full cache
        self._write = jax.jit(write, donate_argnums=(0,))
        self._clear = jax.jit(clear, donate_argnums=(0,))

    # -- slot lifecycle -----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def write_prefill(self, slot: int, req_cache, length: int) -> None:
        """Insert a single-request prefill cache (batch 1) into ``slot``."""
        self.cache = self._write(self.cache, req_cache, jnp.int32(slot))
        self.lengths[slot] = length
        self.active[slot] = True

    def free(self, slot: int) -> None:
        """Evict: zero the slot's row (hygiene; masking is the correctness
        mechanism) and return it to the free list."""
        self.cache = self._clear(self.cache, jnp.int32(slot))
        self.lengths[slot] = 0
        self.active[slot] = False
        self._free.append(slot)


# ---------------------------------------------------------------------------
# Paged block table
# ---------------------------------------------------------------------------


def pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to [1, cap].  Shared by the
    engine's gather-width bucketing and the pool's swap padding so jitted
    variants stay O(log cap)."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


@dataclass
class SwappedRequest:
    """Host-side store of one preempted-by-swap request's device state.

    ``host`` mirrors the cache pytree: paged leaves hold the request's
    gathered blocks (padded to a power of two with trash-block copies so
    the gather/scatter jits compile O(log nb) variants), state leaves hold
    the slot's row.  Swap-in writes it back bit-identical into freshly
    allocated blocks / a freshly allocated slot.
    """

    host: Any
    n_blocks: int  # live blocks to re-allocate (excludes padding)
    n_padded: int  # gather width actually stored
    length: int  # pool lengths[slot] at swap-out
    nbytes: int  # live bytes moved out (telemetry)


class BlockAllocator:
    """Free-list allocator over block ids ``1..num_blocks-1`` (0 = trash).

    Host-side and strict: double-frees and foreign ids raise instead of
    silently corrupting the table (a stale free would hand one block to two
    live requests — the exact cross-request KV leak the pool must prevent).
    """

    TRASH = 0

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 usable + trash), got {num_blocks}")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(1, num_blocks))[::-1]  # pop() -> block 1 first
        self._live: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._live)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n blocks, or None (allocation is all-or-nothing)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._live.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._live:
                raise ValueError(f"double-free or foreign block id {b}")
            self._live.remove(b)
            self._free.append(b)


def paged_layout(model, max_len: int):
    """Discover (slot_axis, seq_axis-or-None) per cache leaf via eval_shape.

    Returns (axes, seq_axes, paged): pytrees matching the cache structure
    with int leaves — ``paged`` uses 1/0 (python bools/ints keep tree.map
    happy where None would not)."""
    axes = slot_axes(model, max_len)
    c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
    c2 = jax.eval_shape(lambda: model.init_cache(1, 2 * max_len))

    def seq_ax(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        return -1  # state leaf: no sequence axis

    seq_axes = jax.tree.map(seq_ax, c1, c2)
    paged = jax.tree.map(lambda s: int(s >= 0), seq_axes)
    return axes, seq_axes, paged


class BlockPool:
    """Paged KV block table + per-slot state arena.

    Paged leaves replace their ``(batch, seq)`` axis pair with
    ``(num_blocks, block_size)``; a request's KV rows live at logical
    position ``t`` in block ``table[t // block_size]``, offset
    ``t % block_size``.  State leaves (rwkv/mamba) keep a ``max_slots``
    slot arena exactly like :class:`KVPool`.

    The pool only manages memory: block tables, the free lists, and the
    arena buffers.  All device writes happen inside the engine's jitted
    chunk-prefill / decode calls (which receive the arena donated), so the
    pool never dispatches per-token work.
    """

    def __init__(
        self,
        model,
        max_slots: int,
        max_len: int,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        watermark: int = 0,
    ):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.block_size = block_size
        self.watermark = watermark  # free blocks ADMISSIONS must leave untouched
        self.nb_max = -(-max_len // block_size)  # blocks per request, worst case
        if num_blocks is None:
            num_blocks = max_slots * self.nb_max + 1  # worst case + trash
        self.num_blocks = num_blocks
        self.axes, self.seq_axes, self.paged = paged_layout(model, max_len)
        self.has_paged = any(jax.tree.leaves(self.paged))

        c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))

        def arena_shape(leaf, slot_ax, seq_ax, pg):
            shape = list(leaf.shape)
            if pg:
                if seq_ax != slot_ax + 1:
                    raise ValueError(
                        f"paged leaf needs adjacent (batch, seq) axes, got "
                        f"slot={slot_ax} seq={seq_ax} shape={leaf.shape}"
                    )
                shape[slot_ax : seq_ax + 1] = [num_blocks, block_size]
            else:
                shape[slot_ax] = max_slots
            return jnp.zeros(shape, leaf.dtype)

        self.cache = jax.tree.map(arena_shape, c1, self.axes, self.seq_axes, self.paged)
        self.allocator = BlockAllocator(num_blocks) if self.has_paged else None
        self.block_table = np.zeros((max_slots, self.nb_max), np.int32)  # 0 = trash
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self._free_slots: List[int] = list(range(max_slots))[::-1]
        self._held: Dict[int, List[int]] = {}
        self.peak_blocks = 0

        def clear_state(arena, slot):
            def one(a, ax, pg):
                return a if pg else clear_slot_leaf(a, ax, slot)

            return jax.tree.map(one, arena, self.axes, self.paged)

        # eviction hygiene for state rows; paged blocks need no zeroing
        # (kv_len masking is the correctness mechanism for stale rows)
        self._clear_state = jax.jit(clear_state, donate_argnums=(0,))

        def swap_gather(arena, blocks, slot):
            # paged leaves: the request's blocks; state leaves: the slot row
            def one(a, ax, pg):
                if pg:
                    return jnp.take(a, blocks, axis=ax)
                return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            return jax.tree.map(one, arena, self.axes, self.paged)

        def swap_scatter(arena, host, blocks, slot):
            # padding entries in ``blocks`` are TRASH duplicates: their rows
            # carry gathered trash content back into the trash block — no-ops
            def one(a, h, ax, pg):
                if pg:
                    idx = (slice(None),) * ax + (blocks,)
                    return a.at[idx].set(h.astype(a.dtype))
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, h.astype(a.dtype), starts)

            return jax.tree.map(one, arena, host, self.axes, self.paged)

        self._swap_gather = jax.jit(swap_gather)
        self._swap_scatter = jax.jit(swap_scatter, donate_argnums=(0,))

        def state_save(arena, slot):
            # state leaves: the slot's row; paged leaves: an empty slice so
            # the pytree structure round-trips through save/restore
            def one(a, ax, pg):
                if pg:
                    return jax.lax.slice_in_dim(a, 0, 0, axis=0)
                return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)

            return jax.tree.map(one, arena, self.axes, self.paged)

        def state_restore(arena, rows, slot):
            def one(a, r, ax, pg):
                if pg:
                    return a
                starts = [jnp.int32(0)] * a.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(a, r.astype(a.dtype), starts)

            return jax.tree.map(one, arena, rows, self.axes, self.paged)

        def zero_rows(arena, pages, offs):
            # un-scatter: zero the addressed (page, offset) KV rows; state
            # leaves pass through (they roll back via the state checkpoint)
            def one(a, ax, pg):
                if not pg:
                    return a
                idx = (slice(None),) * ax + (pages, offs)
                return a.at[idx].set(jnp.zeros((), a.dtype))

            return jax.tree.map(one, arena, self.axes, self.paged)

        self._state_save = jax.jit(state_save)
        self._state_restore = jax.jit(state_restore, donate_argnums=(0,))
        self._zero_rows = jax.jit(zero_rows, donate_argnums=(0,))

    # -- accounting ---------------------------------------------------------

    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def n_free_blocks(self) -> int:
        return self.allocator.n_free if self.allocator else 0

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.n_live if self.allocator else 0

    def blocks_needed(self, rows: int) -> int:
        return -(-rows // self.block_size) if self.has_paged else 0

    def fits(self, rows: int) -> bool:
        return (not self.has_paged) or self.blocks_needed(rows) <= self.n_free_blocks

    def fits_admission(self, rows: int, reserved: int = 0) -> bool:
        """Admission-time fit: must leave the watermark reserve free (growth
        of already-running requests may consume it; fresh admissions may
        not, so admitting cannot instantly force a preemption).  On an IDLE
        pool the watermark is waived — there is nobody to preempt, and
        holding the reserve would permanently starve any request whose
        first chunk needs it (liveness beats headroom).  ``reserved`` adds
        further off-book claims — e.g. blocks owed to swapped-out requests
        awaiting swap-in."""
        if not self.has_paged:
            return True
        wm = self.watermark if self.active.any() else 0
        return self.blocks_needed(rows) + wm + reserved <= self.n_free_blocks

    def held_blocks(self, slot: int) -> int:
        return len(self._held.get(slot, ()))

    @property
    def has_state(self) -> bool:
        """True when the cache has recurrent-state leaves (rwkv/mamba/conv)
        that live in the per-slot arena rather than the paged blocks."""
        return not all(jax.tree.leaves(self.paged))

    # -- speculative rollback -----------------------------------------------

    def save_state_rows(self, slot: int):
        """Device checkpoint of ``slot``'s recurrent-state rows (the
        pre-draft state carry).  Returns None for pure-KV families."""
        if not self.has_state:
            return None
        return self._state_save(self.cache, jnp.int32(slot))

    def restore_state_rows(self, slot: int, rows) -> None:
        """Write back rows captured by :meth:`save_state_rows`."""
        if rows is None:
            return
        self.cache = self._state_restore(self.cache, rows, jnp.int32(slot))

    def rollback_rows(self, slot: int, start: int, end: int) -> None:
        """Un-scatter speculated KV rows: zero logical rows ``[start, end)``
        of ``slot`` through its block table.  The addressed pages must still
        be held by the slot (zero before :meth:`shrink_to`, not after).  The
        row list is padded to a power of two with trash-block redirects
        (block 0, offset 0) so the jitted scatter compiles O(log) variants —
        zeroing the trash block is harmless by definition."""
        if not self.has_paged or end <= start:
            return
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        bs = self.block_size
        pages = [int(self.block_table[slot, r // bs]) for r in range(start, end)]
        offs = [r % bs for r in range(start, end)]
        p = pow2_bucket(len(pages), max(1, self.nb_max * bs))
        pages += [BlockAllocator.TRASH] * (p - len(pages))
        offs += [0] * (p - len(offs))
        self.cache = self._zero_rows(
            self.cache, jnp.asarray(pages, jnp.int32), jnp.asarray(offs, jnp.int32)
        )

    def shrink_to(self, slot: int, rows: int) -> None:
        """Release blocks allocated past ``rows`` KV rows (speculative
        growth that rejection rolled back).  Blocks are freed in REVERSE
        allocation order so the allocator's free stack returns to exactly
        its pre-speculation state — a never-speculated pool and a
        rolled-back one hand out identical block ids from here on."""
        if not self.has_paged:
            return
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        need = max(self.blocks_needed(rows), 0)
        held = self._held[slot]
        if len(held) <= need:
            return
        extra = held[need:]
        del held[need:]
        self.block_table[slot, need : need + len(extra)] = 0
        self.allocator.free(list(reversed(extra)))

    # -- request lifecycle --------------------------------------------------

    def admit(self, rows: int) -> Optional[int]:
        """Allocate a slot + the request's full block need (``rows`` KV
        rows).  Returns the slot, or None if either resource is exhausted."""
        if not self._free_slots:
            return None
        blocks: List[int] = []
        if self.has_paged:
            got = self.allocator.alloc(self.blocks_needed(rows))
            if got is None:
                return None
            blocks = got
        slot = self._free_slots.pop()
        self._held[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, : len(blocks)] = blocks
        self.lengths[slot] = 0
        self.active[slot] = True
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return slot

    def ensure_capacity(self, slot: int, rows: int) -> bool:
        """Allocate-on-boundary: grow ``slot`` to cover ``rows`` KV rows,
        allocating only the blocks past its current holding (one block per
        crossed boundary).  All-or-nothing; returns False when the pool
        cannot supply the growth (the caller preempts a victim and
        retries).  Growth deliberately ignores the watermark — the reserve
        exists exactly so running requests can cross a boundary without an
        immediate preemption."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        if not self.has_paged:
            return True
        need = self.blocks_needed(rows)
        held = len(self._held[slot])
        if need <= held:
            return True
        got = self.allocator.alloc(need - held)
        if got is None:
            return False
        self._held[slot].extend(got)
        self.block_table[slot, held : held + len(got)] = got
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return True

    def _pad_blocks(self, blocks: List[int]) -> List[int]:
        """Pad a block list to a power of two with TRASH duplicates so the
        swap gather/scatter jits compile O(log nb_max) shape variants."""
        p = pow2_bucket(max(1, len(blocks)), max(1, self.nb_max))
        return list(blocks) + [BlockAllocator.TRASH] * (p - len(blocks))

    def swap_out(self, slot: int) -> SwappedRequest:
        """Copy the slot's blocks + state rows to host and free everything.

        The returned :class:`SwappedRequest` is the request's complete
        device state; :meth:`swap_in` restores it bit-identical."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        blocks = list(self._held.get(slot, ()))
        padded = self._pad_blocks(blocks)
        host = jax.device_get(
            self._swap_gather(self.cache, jnp.asarray(padded, jnp.int32), jnp.int32(slot))
        )
        live_frac_num, live_frac_den = max(1, len(blocks)), len(padded)
        nbytes = 0
        for h, pg in zip(jax.tree.leaves(host), jax.tree.leaves(self.paged)):
            nbytes += h.nbytes * live_frac_num // live_frac_den if pg else h.nbytes
        sw = SwappedRequest(
            host=host, n_blocks=len(blocks), n_padded=len(padded),
            length=int(self.lengths[slot]), nbytes=nbytes,
        )
        self.free(slot)
        return sw

    def swap_in(self, sw: SwappedRequest) -> Optional[int]:
        """Restore a swapped request into a fresh slot + fresh blocks.
        Returns the new slot, or None when slots/blocks are unavailable
        (all-or-nothing, so a failed swap-in changes nothing)."""
        if not self._free_slots:
            return None
        blocks: List[int] = []
        if self.has_paged and sw.n_blocks:
            got = self.allocator.alloc(sw.n_blocks)
            if got is None:
                return None
            blocks = got
        slot = self._free_slots.pop()
        self._held[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, : len(blocks)] = blocks
        padded = blocks + [BlockAllocator.TRASH] * (sw.n_padded - len(blocks))
        self.cache = self._swap_scatter(
            self.cache, sw.host, jnp.asarray(padded, jnp.int32), jnp.int32(slot)
        )
        self.lengths[slot] = sw.length
        self.active[slot] = True
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return slot

    def free(self, slot: int) -> None:
        """Evict: return the slot's blocks, zero its state rows and table."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        if self._held.get(slot):
            self.allocator.free(self._held[slot])
        self._held.pop(slot, None)
        self.block_table[slot, :] = 0
        self.lengths[slot] = 0
        self.active[slot] = False
        self.cache = self._clear_state(self.cache, jnp.int32(slot))
        self._free_slots.append(slot)
