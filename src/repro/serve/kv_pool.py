"""Slot-based KV-cache pool for continuous batching.

The pool owns ONE fixed cache arena allocated via ``model.init_cache``
with batch = ``max_slots`` and sequence capacity = ``max_len``.  Each slot
holds one in-flight request; decode always runs over the full arena, so the
decode step compiles exactly once regardless of which requests come and go.
Correctness across slots relies on two invariants:

  * every attention read is masked by the slot's own length (``kv_len`` in
    ``causal_window_mask``), so stale KV beyond a slot's frontier — from a
    previous occupant or from the zero-init — is never attended;
  * recurrent state (rwkv/mamba) is fully overwritten on admission and
    zeroed on eviction, so state families cannot leak either.

Admission inserts a freshly prefilled single-request cache (batch 1, length
= the prompt length) into the slot's row.  The slot axis of every cache leaf
is *discovered*, not hard-coded: we diff ``eval_shape`` of ``init_cache``
for batch 1 vs batch 2, which keeps the pool family-agnostic (dense KV
stacks, rwkv state tuples, hybrid mamba+KV mixtures) and robust to new
cache layouts.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def slot_axes(model, max_len: int):
    """Pytree (matching the cache structure) of each leaf's slot-axis index."""
    c1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
    c2 = jax.eval_shape(lambda: model.init_cache(2, max_len))

    def ax(a, b) -> int:
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis found in cache leaf {a.shape}")

    return jax.tree.map(ax, c1, c2)


def write_slot_leaf(dst: jax.Array, src: jax.Array, axis: int, slot) -> jax.Array:
    """Write ``src`` (slot-axis size 1, other axes <= dst's) at ``slot``."""
    starts = [jnp.int32(0)] * dst.ndim
    starts[axis] = jnp.asarray(slot, jnp.int32)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)


def clear_slot_leaf(dst: jax.Array, axis: int, slot) -> jax.Array:
    """Zero the size-1 row of ``dst`` at ``slot`` along ``axis``."""
    shape = list(dst.shape)
    shape[axis] = 1
    return write_slot_leaf(dst, jnp.zeros(shape, dst.dtype), axis, slot)


class KVPool:
    """Fixed ``max_slots`` x ``max_len`` cache arena with per-slot lengths."""

    def __init__(self, model, max_slots: int, max_len: int):
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.cache = model.init_cache(max_slots, max_len)
        self.axes = slot_axes(model, max_len)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self._free: List[int] = list(range(max_slots))[::-1]  # pop() -> slot 0 first

        def write(arena, req_cache, slot):
            return jax.tree.map(
                lambda dst, src, a: write_slot_leaf(dst, src, a, slot),
                arena, req_cache, self.axes,
            )

        def clear(arena, slot):
            return jax.tree.map(
                lambda dst, a: clear_slot_leaf(dst, a, slot), arena, self.axes
            )

        # jitted so repeated admissions/evictions with the same request shape
        # reuse the compiled scatter; the old arena is dead after each call,
        # so donate it and update in place instead of copying the full cache
        self._write = jax.jit(write, donate_argnums=(0,))
        self._clear = jax.jit(clear, donate_argnums=(0,))

    # -- slot lifecycle -----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def write_prefill(self, slot: int, req_cache, length: int) -> None:
        """Insert a single-request prefill cache (batch 1) into ``slot``."""
        self.cache = self._write(self.cache, req_cache, jnp.int32(slot))
        self.lengths[slot] = length
        self.active[slot] = True

    def free(self, slot: int) -> None:
        """Evict: zero the slot's row (hygiene; masking is the correctness
        mechanism) and return it to the free list."""
        self.cache = self._clear(self.cache, jnp.int32(slot))
        self.lengths[slot] = 0
        self.active[slot] = False
        self._free.append(slot)
