"""Request queue + admission policies for the serving engines.

The scheduler is deliberately host-side and tiny: it tracks arrival times
(in engine decode-step ticks), validates feasibility against the KV
capacity, and hands out admissible requests as capacity frees up under a
selectable :class:`AdmissionPolicy`:

  * ``FIFO``     — submission order (the PR-1 behavior, still the default);
  * ``PRIORITY`` — higher ``Request.priority`` first, FIFO within a level;
  * ``DEADLINE`` — earliest ``Request.deadline`` first (EDF), deadline-less
    requests last, FIFO among equals.

Admission is *best-effort* under a capacity filter: a request that does not
currently fit (e.g. not enough free KV blocks after the watermark reserve
and the blocks owed to in-flight swap-ins) is skipped this tick and
retried later, so one huge request cannot head-of-line-block small ones.

The scheduler is also the *preemption* policy: when a running request
cannot grow (allocate-on-boundary failed), :meth:`Scheduler.select_victim`
picks who yields — the mirror image of the admission order (lowest
priority first / latest deadline first / newest submission first).
Everything device-side (arena/block writes, decode, swap copies) lives in
``engine.PagedEngine`` / ``kv_pool``; the lifecycle states themselves in
``serve.lifecycle``.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, List, Optional

import numpy as np

from ..core.glass import GlassParams
from .sampling import SamplingParams


class AdmissionPolicy(str, Enum):
    FIFO = "fifo"
    PRIORITY = "priority"
    DEADLINE = "deadline"


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new: int  # number of tokens to generate (incl. the first post-prefill token)
    arrival: int = 0  # engine step at which the request becomes visible
    priority: int = 0  # larger = more urgent (PRIORITY policy only)
    deadline: Optional[int] = None  # absolute engine step (DEADLINE policy only)
    # per-request generation policy (None = engine defaults; a bare Request
    # through the legacy submit()/run() path decodes greedy at the engine's
    # GLASS config — see PagedEngine.add_request for the first-class API)
    sampling: Optional[SamplingParams] = None
    glass: Optional[GlassParams] = None


@dataclass
class FinishedRequest:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (max_new,) generated ids
    arrival: int
    admitted_step: int
    finished_step: int


@dataclass
class RequestOutput:
    """One request's streaming update from ``PagedEngine.step()``.

    Every live request that produced tokens this tick yields one of these
    (``new_tokens`` is the delta since the previous step); the final update
    has ``finished=True`` with a ``finish_reason`` and carries the full
    cumulative stream — structurally a superset of the legacy
    :class:`FinishedRequest`, so ``run()`` can return it unchanged.
    """

    uid: int
    prompt: np.ndarray
    new_tokens: np.ndarray  # (delta,) ids emitted since the previous step()
    tokens: np.ndarray  # (n,) cumulative generated ids
    finished: bool
    finish_reason: Optional[str]  # length | stop | eos | aborted (None while live)
    arrival: int
    admitted_step: int
    finished_step: int  # -1 until finished


@dataclass
class Scheduler:
    """Queue + admission with a KV-feasibility check at submit time.

    A request needs ``len(prompt) + max_new - 1`` cache rows (the last
    sampled token is returned but never written back), so infeasible
    requests are rejected at submit time instead of deadlocking the queue.
    """

    max_len: int
    policy: AdmissionPolicy = AdmissionPolicy.FIFO
    queue: Deque[Request] = field(default_factory=deque)
    _seq: "itertools.count" = field(default_factory=itertools.count, repr=False)

    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new - 1
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache rows > max_len={self.max_len}"
            )
        req._submit_seq = next(self._seq)  # policy tie-break: submission order
        self.queue.append(req)

    def requeue(self, req: Request) -> None:
        """Re-queue a preempted-for-recompute request.  Feasibility was
        validated at the original submit and ``_submit_seq`` is preserved,
        so the request keeps its place in the policy order instead of
        going to the back of the FIFO tie-break.

        CAUTION: on re-admission the engine replays the request's recorded
        ``outputs`` as *forced* decode tokens.  The caller must therefore
        roll back any speculative state first — a mid-speculation victim
        requeued with provisional draft tokens still in ``outputs`` would
        replay tokens the target tier never verified (the engine's
        ``_rollback_speculation`` slices them off before ever reaching
        here)."""
        assert hasattr(req, "_submit_seq"), "requeue() is for previously submitted requests"
        assert all(q is not req for q in self.queue), "request is already queued"
        self.queue.append(req)

    def remove(self, uid: int) -> Optional[Request]:
        """Drop a queued request by uid (abort support).  Index-based
        removal for the same reason as ``pop_admissible``: the dataclass
        ``__eq__`` compares ndarray prompts and cannot be used on the
        queue.  Returns the removed request, or None if not queued."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                return r
        return None

    def __len__(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[int]:
        return min((r.arrival for r in self.queue), default=None)

    def admission_key(self, r: Request):
        """Admission order under the active policy (lower = admitted
        first).  Public because the engine also uses it to order swap-ins
        — resumption competes in the same policy order as admission."""
        seq = getattr(r, "_submit_seq", 0)
        if self.policy is AdmissionPolicy.PRIORITY:
            return (-r.priority, seq)
        if self.policy is AdmissionPolicy.DEADLINE:
            return (r.deadline if r.deadline is not None else np.inf, seq)
        return (seq,)

    def victim_key(self, r: Request):
        """Preemption order — the mirror image of the admission order:
        lowest priority first (PRIORITY), latest deadline first with
        deadline-less requests before any deadline (DEADLINE), newest
        submission first (FIFO, i.e. LIFO preemption so the oldest work
        keeps its progress)."""
        seq = getattr(r, "_submit_seq", 0)
        if self.policy is AdmissionPolicy.PRIORITY:
            return (r.priority, -seq)
        if self.policy is AdmissionPolicy.DEADLINE:
            return (-(r.deadline if r.deadline is not None else np.inf), -seq)
        return (-seq,)

    def select_victim(self, candidates: List[Request]) -> Optional[Request]:
        """Pick the request that yields its resources under pressure."""
        if not candidates:
            return None
        return min(candidates, key=self.victim_key)

    def drain_arrived(self, now: int) -> List[Request]:
        """Every arrived request, in policy order — the cluster dispatcher's
        global-queue drain.  The cluster holds ONE of these schedulers as
        its global queue (same FIFO / PRIORITY / DEADLINE ranks as the
        per-engine queues), pops arrivals in policy order, and routes each
        to a replica; because the replicas re-sort their own queues under
        the SAME policy, dispatch order is preserved end-to-end and an
        N=1 cluster admits in exactly the single-engine order."""
        return self.pop_admissible(now, len(self.queue))

    def pop_admissible(
        self,
        now: int,
        k: int,
        fits: Optional[Callable[[Request], bool]] = None,
    ) -> List[Request]:
        """Up to ``k`` arrived requests in policy order.

        Not-yet-arrived requests are skipped, not head-of-line blocking:
        arrivals are wall-clock facts, not priorities.  ``fits`` (when
        given) is re-evaluated after every pick so capacity consumed by an
        earlier pick is visible to later ones; requests that do not fit
        stay queued for a later tick."""
        out: List[Request] = []
        while len(out) < k:
            best_i = -1
            for i, r in enumerate(self.queue):
                if r.arrival > now or (fits is not None and not fits(r)):
                    continue
                if best_i < 0 or self.admission_key(r) < self.admission_key(self.queue[best_i]):
                    best_i = i
            if best_i < 0:
                break
            # removal by index, NOT deque.remove(best): equality-based removal
            # would invoke the dataclass __eq__, which compares the ndarray
            # prompt and raises whenever two queued requests share a uid
            out.append(self.queue[best_i])
            del self.queue[best_i]
        return out
