"""Request queue + admission policies for the serving engines.

The scheduler is deliberately host-side and tiny: it tracks arrival times
(in engine decode-step ticks), validates feasibility against the KV
capacity, and hands out admissible requests as capacity frees up under a
selectable :class:`AdmissionPolicy`:

  * ``FIFO``     — submission order (the PR-1 behavior, still the default);
  * ``PRIORITY`` — higher ``Request.priority`` first, FIFO within a level;
  * ``DEADLINE`` — earliest ``Request.deadline`` first (EDF), deadline-less
    requests last, FIFO among equals.

Admission is *best-effort* under a capacity filter: a request that does not
currently fit (e.g. not enough free KV blocks) is skipped this tick and
retried later, so one huge request cannot head-of-line-block small ones.
Everything device-side (arena/block writes, decode) lives in
``engine.ContinuousEngine`` / ``engine.PagedEngine`` / ``kv_pool``.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, List, Optional

import numpy as np


class AdmissionPolicy(str, Enum):
    FIFO = "fifo"
    PRIORITY = "priority"
    DEADLINE = "deadline"


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new: int  # number of tokens to generate (incl. the first post-prefill token)
    arrival: int = 0  # engine step at which the request becomes visible
    priority: int = 0  # larger = more urgent (PRIORITY policy only)
    deadline: Optional[int] = None  # absolute engine step (DEADLINE policy only)


@dataclass
class FinishedRequest:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (max_new,) generated ids
    arrival: int
    admitted_step: int
    finished_step: int


@dataclass
class Scheduler:
    """Queue + admission with a KV-feasibility check at submit time.

    A request needs ``len(prompt) + max_new - 1`` cache rows (the last
    sampled token is returned but never written back), so infeasible
    requests are rejected at submit time instead of deadlocking the queue.
    """

    max_len: int
    policy: AdmissionPolicy = AdmissionPolicy.FIFO
    queue: Deque[Request] = field(default_factory=deque)
    _seq: "itertools.count" = field(default_factory=itertools.count, repr=False)

    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new - 1
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache rows > max_len={self.max_len}"
            )
        req._submit_seq = next(self._seq)  # policy tie-break: submission order
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[int]:
        return min((r.arrival for r in self.queue), default=None)

    def _key(self, r: Request):
        seq = getattr(r, "_submit_seq", 0)
        if self.policy is AdmissionPolicy.PRIORITY:
            return (-r.priority, seq)
        if self.policy is AdmissionPolicy.DEADLINE:
            return (r.deadline if r.deadline is not None else np.inf, seq)
        return (seq,)

    def pop_admissible(
        self,
        now: int,
        k: int,
        fits: Optional[Callable[[Request], bool]] = None,
    ) -> List[Request]:
        """Up to ``k`` arrived requests in policy order.

        Not-yet-arrived requests are skipped, not head-of-line blocking:
        arrivals are wall-clock facts, not priorities.  ``fits`` (when
        given) is re-evaluated after every pick so capacity consumed by an
        earlier pick is visible to later ones; requests that do not fit
        stay queued for a later tick."""
        out: List[Request] = []
        while len(out) < k:
            best_i = -1
            for i, r in enumerate(self.queue):
                if r.arrival > now or (fits is not None and not fits(r)):
                    continue
                if best_i < 0 or self._key(r) < self._key(self.queue[best_i]):
                    best_i = i
            if best_i < 0:
                break
            # removal by index, NOT deque.remove(best): equality-based removal
            # would invoke the dataclass __eq__, which compares the ndarray
            # prompt and raises whenever two queued requests share a uid
            out.append(self.queue[best_i])
            del self.queue[best_i]
        return out
