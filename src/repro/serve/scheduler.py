"""Request queue + admission policy for the continuous-batching engine.

The scheduler is deliberately host-side and tiny: it tracks arrival times
(in engine decode-step ticks), validates feasibility against the KV arena,
and hands out admissible requests FIFO as slots free up.  Everything
device-side (arena writes, decode) lives in ``engine.ContinuousEngine`` /
``kv_pool.KVPool``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new: int  # number of tokens to generate (incl. the first post-prefill token)
    arrival: int = 0  # engine step at which the request becomes visible


@dataclass
class FinishedRequest:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (max_new,) generated ids
    arrival: int
    admitted_step: int
    finished_step: int


@dataclass
class Scheduler:
    """FIFO admission with an arena-feasibility check.

    A request needs ``len(prompt) + max_new - 1`` cache rows (the last
    sampled token is returned but never written back), so infeasible
    requests are rejected at submit time instead of deadlocking the queue.
    """

    max_len: int
    queue: Deque[Request] = field(default_factory=deque)

    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new - 1
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache rows > max_len={self.max_len}"
            )
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def next_arrival(self) -> Optional[int]:
        return min((r.arrival for r in self.queue), default=None)

    def pop_admissible(self, now: int, k: int) -> List[Request]:
        """Up to ``k`` arrived requests, FIFO by submission order.

        Not-yet-arrived requests are skipped, not head-of-line blocking:
        arrivals are wall-clock facts, not priorities."""
        out: List[Request] = []
        if k <= 0:
            return out
        rest: Deque[Request] = deque()
        while self.queue:
            r = self.queue.popleft()
            if len(out) < k and r.arrival <= now:
                out.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return out
