"""Replica-sharded serving: N ``PagedEngine`` replicas behind one queue.

The :class:`ClusterEngine` scales the single-engine serving path across
the ``data`` mesh axis: each replica owns a disjoint ``BlockPool`` shard
(its own block table, allocator, prefix cache, and GLASS arenas) committed
to its own device slice (``launch.mesh.replica_slices`` +
``launch.steps.place_replica``), so the replicas' jitted decode programs
dispatch concurrently while one host-side dispatcher drains a single
global queue.

**Admission** pops the global queue in policy order (the same FIFO /
PRIORITY / DEADLINE ranks as the per-engine schedulers — a request's rank
is preserved end-to-end) and routes each request to the replica with the
lowest admission cost::

    cost(r, req) = pending_tokens(r)                       # load, token units
                 + overflow_weight * max(0, need_blocks(req) - free_blocks(r))
                 - affinity_weight * prefix_hit(r, req)    # rows served free

``pending_tokens`` measures outstanding work in tokens (not requests —
GLASS's per-request density/draft knobs make requests heterogeneous in
cost, which is exactly why round-robin assignment loses); ``free_blocks``
is net of the watermark reserve and blocks owed to swapped/migrating
requests; ``prefix_hit`` probes each replica's prefix cache through the
side-effect-free ``BlockPool.peek_prefix`` (a probe is not a use: no LRU
reorder, no hit/miss skew), so a request lands on the replica that
already holds the longest matching chain when loads are comparable.
``admission="round_robin"`` is the naive baseline the benchmark beats.

**Migration** rebalances under hot-spot pressure: when the hottest
replica's ``pending_tokens`` exceeds the coldest's by
``MigrationConfig.imbalance_tokens`` and the cold replica can host the
victim *now*, the scheduler's victim choice (mirror of admission order)
moves one running request over the ``SwappedRequest`` wire format — a
FULL swap-out on the source (shared prefix blocks copied like private
ones; physical ids mean nothing across pools), the portable
``SwappedWire`` payload, and a cross-pool splice (blocks + GLASS slot
rows + recurrent-state rows) on the destination::

    RUNNING/SPECULATING/PREFILLING ─▶ PREEMPTED_SWAPPED ─▶ MIGRATING ─▶ RUNNING
      (SPECULATING rolls back first;        (source)      (in flight)  (dest:
       PREFILLING hands off at a chunk                                  splice)
       boundary and resumes PREFILLING)

Migrated streams are bit-identical to an undisturbed single-engine run:
the swap format is proven bit-exact, GLASS rows are copied not rebuilt,
recurrent state rows ride in the same store, sampling is counter-based
(pure function of seed × position × logits), and a mid-prefill handoff
replays nothing — the partial stat left-fold travels with the ticket and
keeps accumulating at the destination over the same chunk boundaries.

Single-process by design: replicas are device-sliced, not host-sharded.
The host-side dispatcher, block accounting, and ticket handoff are plain
Python; a multi-host deployment would serialize ``MigrationTicket`` /
``SwappedWire`` (already host numpy + ints throughout) over the wire and
run one dispatcher process — the device-side machinery is unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from ..core.fusion import GlassConfig
from ..core.glass import GlassParams
from ..launch.mesh import replica_slices
from ..launch.steps import place_replica
from .engine import MigrationTicket, PagedEngine
from .lifecycle import ReqState
from .sampling import SamplingParams
from .scheduler import AdmissionPolicy, Request, RequestOutput, Scheduler


@dataclass(frozen=True)
class MigrationConfig:
    """Hot-spot rebalancing knobs.

    ``imbalance_tokens`` is the minimum pending-token gap between the
    hottest and coldest replica before a migration pays for itself (the
    move costs one swap-out + one swap-in of the victim's whole context);
    ``min_remaining`` skips nearly-finished victims (their remaining work
    cannot amortize the move); ``max_per_tick`` bounds the dispatcher's
    per-tick migration work so a pathological imbalance cannot stall the
    serving loop."""

    enabled: bool = True
    imbalance_tokens: int = 48
    min_remaining: int = 4
    max_per_tick: int = 1


class ClusterEngine:
    """N ``PagedEngine`` replicas draining one global queue.

    Replica construction mirrors ``PagedEngine`` (every ``**engine_kw`` is
    per-replica: ``num_blocks`` is each shard's size, so N replicas at
    ``B`` blocks compare against one big engine at ``N*B``).  With a
    ``mesh`` (``make_host_mesh(data=N, model=M)``), replica ``r``'s params,
    GLASS prior, and KV arena are committed to data-slice ``r`` so the
    replicas' device programs overlap; without one, all replicas share the
    default device (correct, serialized — the single-device test fallback).
    """

    def __init__(
        self,
        model,
        params,
        *,
        n_replicas: int,
        mesh=None,
        admission: str = "balanced",  # balanced | round_robin
        migration: Optional[MigrationConfig] = None,
        policy: AdmissionPolicy = AdmissionPolicy.FIFO,
        glass: Optional[GlassConfig] = None,
        global_prior=None,
        overflow_weight: float = 8.0,
        affinity_weight: float = 1.0,
        **engine_kw,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if admission not in ("balanced", "round_robin"):
            raise ValueError(f"unknown admission mode {admission!r}")
        self.admission = admission
        self.migration = migration if migration is not None else MigrationConfig()
        self.overflow_weight = overflow_weight
        self.affinity_weight = affinity_weight
        slices = (
            replica_slices(mesh, n_replicas) if mesh is not None
            else [None] * n_replicas
        )
        self.replicas: List[PagedEngine] = []
        for r, devs in enumerate(slices):
            eng = PagedEngine(
                model,
                place_replica(params, devs),
                glass=glass,
                global_prior=(
                    place_replica(global_prior, devs)
                    if global_prior is not None else None
                ),
                policy=policy,
                **engine_kw,
            )
            eng.pool.cache = place_replica(eng.pool.cache, devs)
            eng.programs.namespace = f"replica{r}"
            self.replicas.append(eng)
        self.devices = slices
        self.queue = Scheduler(self.replicas[0].scheduler.max_len, policy=policy)
        self.t = 0
        self._rr = 0  # round-robin cursor
        self._auto_uid = 0
        self._owner: Dict[int, int] = {}  # uid -> replica index
        # telemetry
        self.migrations = 0
        self.migration_bytes = 0
        self.occupancy: List[List[int]] = [[] for _ in self.replicas]

    # -- request frontend ---------------------------------------------------

    def add_request(
        self,
        prompt,
        max_new: int,
        *,
        sampling: Optional[SamplingParams] = None,
        glass: Optional[GlassParams] = None,
        uid: Optional[int] = None,
        arrival: Optional[int] = None,
        priority: int = 0,
        deadline: Optional[int] = None,
    ) -> int:
        """Enqueue one request on the GLOBAL queue (arrival in cluster
        ticks); the dispatcher routes it to a replica when it arrives.
        Mirrors ``PagedEngine.add_request``."""
        if uid is None:
            used = self._owner.keys() | {r.uid for r in self.queue.queue}
            while self._auto_uid in used:
                self._auto_uid += 1
            uid = self._auto_uid
            self._auto_uid += 1
        req = Request(
            uid=uid, prompt=np.asarray(prompt, np.int32), max_new=max_new,
            arrival=self.t if arrival is None else arrival,
            priority=priority, deadline=deadline,
            sampling=sampling, glass=glass,
        )
        self.queue.submit(req)
        return uid

    def abort(self, uid: int) -> Optional[RequestOutput]:
        """Cancel a request wherever it lives: still in the global queue,
        queued/live/swapped/MIGRATING on its replica — the replica's abort
        releases exactly what it holds (a migrated-in store pins nothing,
        so aborting mid-migration releases both sides by construction)."""
        owner = self._owner.get(uid)
        if owner is not None:
            return self.replicas[owner].abort(uid)
        r = self.queue.remove(uid)
        if r is None:
            return None
        return RequestOutput(
            uid=uid, prompt=np.asarray(r.prompt, np.int32),
            new_tokens=np.zeros((0,), np.int32), tokens=np.zeros((0,), np.int32),
            finished=True, finish_reason="aborted",
            arrival=r.arrival, admitted_step=-1, finished_step=self.t,
        )

    # -- admission scoring --------------------------------------------------

    def _admission_cost(self, eng: PagedEngine, req: Request) -> float:
        ci = eng.admission_cost_inputs(req.prompt)
        rows = len(req.prompt) + req.max_new - 1 - ci["prefix_hit"]
        need = eng.pool.blocks_needed(rows)
        return (
            ci["pending_tokens"]
            + self.overflow_weight * max(0, need - ci["free_blocks"])
            - self.affinity_weight * ci["prefix_hit"]
        )

    def _route(self, req: Request) -> int:
        if self.admission == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % len(self.replicas)
            return i
        costs = [self._admission_cost(eng, req) for eng in self.replicas]
        return int(np.argmin(costs))  # ties -> lowest replica index

    def _dispatch_tick(self) -> None:
        for req in self.queue.drain_arrived(self.t):
            i = self._route(req)
            # the replica clocks arrivals in ITS ticks; the request is due
            # now, so it becomes admissible on the replica immediately (the
            # cluster-level admission wait is measured in cluster ticks
            # against the original arrival)
            req.arrival = self.replicas[i].t
            self.replicas[i]._submit(req)
            self._owner[req.uid] = i

    # -- migration ----------------------------------------------------------

    def migrate(self, uid: int, dst: int) -> None:
        """Move one live request to replica ``dst`` over the portable swap
        wire.  Public so tests (and external balancers) can force a
        migration; ``_migrate_tick`` drives it under hot-spot pressure."""
        src = self._owner[uid]
        if src == dst:
            return
        ticket = self.replicas[src].migrate_out(uid)
        self.migrations += 1
        self.migration_bytes += ticket.wire.nbytes
        self.replicas[dst].migrate_in(ticket)
        self._owner[uid] = dst

    def _can_host(self, eng: PagedEngine, rows: int) -> bool:
        """Destination fit check BEFORE detaching the victim: a migrated
        request that cannot splice would strand in MIGRATING."""
        if not eng.pool.n_free_slots:
            return False
        if not eng.pool.has_paged:
            return True
        reserved = sum(
            e.swap.n_blocks
            for e in eng.lc.in_state(ReqState.PREEMPTED_SWAPPED, ReqState.MIGRATING)
        )
        need = eng.pool.blocks_needed(rows)
        return need + reserved + eng.pool.watermark <= eng.pool.n_available_blocks

    def _migrate_tick(self) -> None:
        cfg = self.migration
        if not cfg.enabled or len(self.replicas) < 2:
            return
        for _ in range(cfg.max_per_tick):
            loads = [eng.pending_tokens for eng in self.replicas]
            hot = int(np.argmax(loads))
            cold = int(np.argmin(loads))
            if loads[hot] - loads[cold] < cfg.imbalance_tokens:
                return
            eng = self.replicas[hot]
            cands = [
                e for e in eng.lc.in_state(ReqState.RUNNING)
                if e.req.max_new - len(e.outputs) >= cfg.min_remaining
            ]
            vr = eng.scheduler.select_victim([e.req for e in cands])
            if vr is None:
                return
            victim = next(e for e in cands if e.req is vr)
            rows = int(eng.pool.lengths[victim.slot])
            if not self._can_host(self.replicas[cold], rows):
                return
            self.migrate(victim.uid, cold)

    # -- serving loop -------------------------------------------------------

    def step(self) -> List[RequestOutput]:
        """One cluster tick: dispatch arrivals (policy order, cost-scored
        routing), rebalance under hot-spot pressure, then step every
        replica that has work.  Returns the concatenated ``RequestOutput``
        stream — a migrated request keeps streaming under its uid with no
        duplicated deltas (its ``emitted`` cursor travels in the ticket)."""
        self._dispatch_tick()
        self._migrate_tick()
        outs: List[RequestOutput] = []
        for i, eng in enumerate(self.replicas):
            if eng._work_remaining():
                outs.extend(eng.step())
            self.occupancy[i].append(eng.pool.blocks_in_use)
        self.t += 1
        return outs

    def _work_remaining(self) -> bool:
        return bool(len(self.queue)) or any(
            eng._work_remaining() for eng in self.replicas
        )

    def run(self, max_steps: Optional[int] = None) -> Dict[int, RequestOutput]:
        """Serve until the global queue and every replica drain; returns
        ``{uid: final RequestOutput}`` (streaming deltas filtered)."""
        if max_steps is None:
            queued = list(self.queue.queue)
            pending = [r for eng in self.replicas for r in eng._inflight_requests()]
            chunks = self.replicas[0].chunk_tokens
            base = sum(
                r.max_new + -(-len(r.prompt) // chunks) for r in queued + pending
            )
            arrivals = [r.arrival for r in queued] + [0]
            max_steps = self.t + max(arrivals) + base * 4 + 16 + len(queued) + 8
        done: Dict[int, RequestOutput] = {}
        while self._work_remaining():
            if self.t > max_steps:
                raise RuntimeError(f"ClusterEngine did not drain in {max_steps} steps")
            for f in self.step():
                if f.finished:
                    done[f.uid] = f
        return done

    # -- telemetry ----------------------------------------------------------

    @property
    def admission_waits(self) -> List[int]:
        """First-admission latencies aggregated across replicas, in ENGINE
        ticks (directly comparable with a single ``PagedEngine``'s): a
        request's arrival is stamped with its replica's clock at dispatch,
        so the replica-recorded wait is the queue-to-prefill latency the
        routing decision produced.  Migrated requests never re-record (the
        destination adopts them pre-admitted)."""
        return [w for eng in self.replicas for w in eng.admission_waits]

    def admission_wait_p99(self) -> float:
        waits = self.admission_waits
        if not waits:
            return 0.0
        return float(np.percentile(np.asarray(waits, np.float64), 99))

    def occupancy_variance(self) -> float:
        """Variance across replicas of mean blocks-in-use per tick — the
        balance headline (0 for a perfectly even cluster)."""
        means = [float(np.mean(o)) if o else 0.0 for o in self.occupancy]
        return float(np.var(means))

    def telemetry(self) -> Dict[str, object]:
        return dict(
            drain_ticks=self.t,
            admission_wait_p99=self.admission_wait_p99(),
            admission_waits=list(self.admission_waits),
            migrations=self.migrations,
            migration_bytes=self.migration_bytes,
            occupancy_variance=self.occupancy_variance(),
            per_replica=[
                dict(
                    swap_ins=eng.swap_ins,
                    preemptions=eng.preempt_count,
                    migrations_in=eng.migrations_in,
                    migrations_out=eng.migrations_out,
                    prefix_hits=(
                        eng.pool.prefix_cache.hits
                        if eng.pool.prefix_cache is not None else 0
                    ),
                    mean_blocks=float(np.mean(o)) if (o := self.occupancy[i]) else 0.0,
                )
                for i, eng in enumerate(self.replicas)
            ],
        )
