"""Fault-tolerance plumbing: step-time watchdog, heartbeats, re-mesh planning.

On a real multi-host deployment each host runs a ``Heartbeat`` writer; the
coordinator (or every peer — it is just file mtimes) runs ``check_peers`` and
feeds dead/straggling hosts into ``plan_elastic_mesh`` to pick the largest
valid mesh for restart from the latest checkpoint (checkpoints are
mesh-independent — see checkpoint.ckpt).  The watchdog's EWMA + k*sigma rule
flags stragglers *before* they fail, the usual early signal on 1000+ nodes.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple


@dataclass
class StepWatchdog:
    """EWMA step-time tracker: flags steps slower than mean + k*std."""

    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup_steps: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    slow_steps: List[Tuple[int, float]] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.n += 1
        if self.n <= self.warmup_steps:
            self.mean = dt if self.n == 1 else (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        slow = dt > self.mean + self.k_sigma * max(self.var, 1e-12) ** 0.5
        if slow:
            self.slow_steps.append((step, dt))
        else:  # only track healthy steps in the baseline
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return slow


class Heartbeat:
    """Per-host liveness file: mtime is the signal, content is diagnostics."""

    def __init__(self, directory: str | Path, host_id: int):
        self.path = Path(directory) / f"heartbeat_{host_id:05d}.json"
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id

    def beat(self, step: int, extra: Optional[Dict] = None):
        payload = {"host": self.host_id, "step": step, "t": time.time(), **(extra or {})}
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload))
        os.replace(tmp, self.path)


def check_peers(directory: str | Path, timeout_s: float, now: Optional[float] = None) -> Dict[str, List[int]]:
    """Classify hosts by heartbeat freshness. Returns {alive, dead}."""
    now = now if now is not None else time.time()
    alive, dead = [], []
    for p in sorted(Path(directory).glob("heartbeat_*.json")):
        host = int(p.stem.split("_")[1])
        try:
            t = json.loads(p.read_text())["t"]
        except Exception:  # torn write — treat as stale, next beat fixes it
            t = p.stat().st_mtime
        (alive if now - t <= timeout_s else dead).append(host)
    return {"alive": alive, "dead": dead}


def plan_elastic_mesh(
    n_healthy_hosts: int,
    chips_per_host: int,
    model_parallel: int,
) -> Optional[Tuple[int, int]]:
    """Largest (data, model) mesh on the healthy set.

    Keeps the model axis fixed (TP degree is architectural) and shrinks the
    data axis to the largest full multiple — the restart then restores the
    latest checkpoint with the new shardings (elastic data parallelism)."""
    chips = n_healthy_hosts * chips_per_host
    if chips < model_parallel:
        return None
    data = chips // model_parallel
    # largest power-of-two data axis keeps batch-divisibility guarantees
    p = 1
    while p * 2 <= data:
        p *= 2
    return (p, model_parallel)
