"""GLASS end-to-end pipeline: prior computation, mask building, compaction.

Typical deployment flow (paper Fig. 2):

  1. offline, once per model:
       prior = compute_global_prior(model, params, rng, nps_cfg, variant)
  2. per request, at the end of prefill:
       logits, cache, local = model.prefill(params, inputs, max_len)
       masks = build_masks(local, prior, gcfg)
  3. steady-state decode with the compact FFN:
       compact = compact_params(model, params, masks.idx)
       logits, cache = model.decode_step(params, tok, cache, n,
                                         compact_layers=compact)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..models.ffn import compact_ffn_params
from ..models.moe import compact_moe_params
from . import importance
from .fusion import GlassConfig, glass_scores, select
from .nps import NPSConfig, nps_corpus, teacher_forced_batch


@dataclass(frozen=True)
class GlassParams:
    """Request-scoped GLASS policy: the per-request view of
    :class:`~repro.core.fusion.GlassConfig`.

    Every field defaults to None = "inherit the engine's config".  The
    engine config acts as the *capacity tier*: a request's density (and
    draft density, ``density * draft_ratio``) may be at most the engine's
    — per-request selections at a lower density NEST inside the capacity
    selection (same fused scores, same stable tie-break; the
    :func:`build_tiered_masks` nesting argument), which is what lets one
    fixed-shape slot arena serve mixed densities.  ``spec_k`` is the
    request's draft length per speculative round (0 = never speculate;
    requests with different spec_k share a tick — the round drafts the
    minimum).
    """

    density: Optional[float] = None
    draft_ratio: Optional[float] = None
    spec_k: Optional[int] = None

    def __post_init__(self):
        if self.density is not None and not (0.0 < self.density <= 1.0):
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        if self.draft_ratio is not None and not (0.0 < self.draft_ratio <= 1.0):
            raise ValueError(f"draft_ratio must be in (0, 1], got {self.draft_ratio}")
        if self.spec_k is not None and self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")

    def resolve(self, gcfg: Optional[GlassConfig], spec_k_default: int
                ) -> "GlassParams":
        """Fill None fields from the engine's config -> a fully concrete
        GlassParams (density/draft_ratio still None when the engine serves
        dense / has no draft tier)."""
        return GlassParams(
            density=self.density if self.density is not None
            else (gcfg.density if gcfg is not None else None),
            draft_ratio=self.draft_ratio if self.draft_ratio is not None
            else (gcfg.draft_ratio if gcfg is not None else None),
            spec_k=self.spec_k if self.spec_k is not None else spec_k_default,
        )


def snapshot_stat_sums(stats):
    """Detachable snapshot of a running GLASS stat-sum pytree, safe to
    retain across requests (e.g. in the serving prefix cache).

    Chunk stats are produced functionally (every merge allocates fresh
    buffers and the prefill jits never donate them), so the snapshot is a
    structural copy with the same immutable leaves — cheap, and bit-exact
    by construction.  ``None`` (no chunks yet) snapshots to ``None``."""
    if stats is None:
        return None
    return jax.tree.map(jnp.asarray, stats)


def restore_stat_sums(snap):
    """Resume accumulation from a :func:`snapshot_stat_sums` snapshot: the
    returned pytree is a valid left operand for
    :func:`~repro.core.fusion.merge_stat_sums`, so
    ``merge(restore(snap), next_chunk_stats)`` continues the fold exactly
    where the snapshotted prefill stopped."""
    if snap is None:
        return None
    return jax.tree.map(jnp.asarray, snap)


@dataclass(frozen=True)
class MaskSet:
    # CAUTION: ``idx`` semantics follow the selection mode.  ``neuron`` /
    # ``shard_balanced`` yield per-unit indices (L, k) — the input
    # ``compact_params`` gathers with.  ``selection="block"`` yields *block*
    # ids (L, nb_keep) for the pallas block-sparse decode kernel; gathering
    # weights with block ids would silently select the wrong units, so the
    # engines refuse ``glass_mode="compact"`` with block selection.
    idx: jax.Array  # (L, k) int32 (MoE: (L, E, k)); block selection: block ids
    mask: jax.Array  # (L, m) f32   (MoE: (L, E, f))
    scores: jax.Array  # fused consensus scores, same shape as mask


def compute_global_prior(
    model: Model,
    params,
    rng: jax.Array,
    npc: NPSConfig,
    variant: str = "A",
    corpus: Optional[jax.Array] = None,
) -> jax.Array:
    """Model-intrinsic importance via NPS (or a provided corpus for the
    Wiki-style ablation).  Returns the per-layer mean importance M^g."""
    if corpus is None:
        corpus = nps_corpus(model, params, rng, npc)
    batches = [
        teacher_forced_batch(corpus[i : i + npc.batch], npc.bos_id)
        for i in range(0, corpus.shape[0], npc.batch)
    ]
    if variant == "A":
        stats = importance.global_activation_stats(model, params, batches)
    elif variant == "I":
        stats = importance.global_impact_stats(model, params, batches)
    else:
        raise ValueError(variant)
    return importance.finalize(stats)


def build_masks(
    local_stats: Dict,
    global_prior: jax.Array,
    gcfg: GlassConfig,
    *,
    slot_axis: bool = False,
) -> MaskSet:
    """Fuse prefill-local and global importance into the decode mask set.

    local_stats: {"sum_abs", "count"} from prefill; global_prior: (L, m).
    lam = 0 -> GRIFFIN (local-only); lam = 1 -> static global mask.

    ``slot_axis=True`` builds per-request masks for continuous batching:
    local_stats leaves are stacked over a leading request axis (one prefill
    per request), the prior stays shared, and the result uses the decode-scan
    layout with the slot axis second — idx (L, B, k), mask (L, B, m) (MoE
    adds the expert axis after B; hybrid keeps its leading singleton)."""
    if slot_axis:
        def one(st):
            ms = build_masks(st, global_prior, gcfg)
            return ms.idx, ms.mask, ms.scores

        idx, mask, scores = jax.vmap(one)(local_stats)
        return MaskSet(
            idx=jnp.moveaxis(idx, 0, 1),
            mask=jnp.moveaxis(mask, 0, 1),
            scores=jnp.moveaxis(scores, 0, 1),
        )
    local = importance.finalize(local_stats)
    if local.ndim == 1:  # hybrid shared block: single (m,) signal
        local = local[None]
        global_prior = global_prior if global_prior.ndim > 1 else global_prior[None]
    scores = glass_scores(local, global_prior, gcfg.lam)
    idx, mask = select(scores, gcfg)
    return MaskSet(idx=idx, mask=mask, scores=scores)


def build_tiered_masks(
    local_stats: Dict,
    global_prior: jax.Array,
    gcfg: GlassConfig,
    *,
    slot_axis: bool = False,
) -> "tuple[MaskSet, MaskSet]":
    """Target + draft mask sets from ONE fused-score pass.

    Both tiers rank the identical consensus scores and select with the same
    stable tie-break, only ``k`` differs (``density`` vs ``density *
    draft_ratio``), so the draft selection is a prefix of the target's
    sorted order: draft-tier active units (and, under ``selection="block"``,
    active block ids) always NEST inside the target tier's.  That nesting is
    what lets a self-speculative decoder treat the draft pass as a strictly
    cheaper approximation of the target pass over the same weights.

    Returns ``(target, draft)``; layouts match :func:`build_masks`
    (including the ``slot_axis=True`` continuous-batching layout).
    """
    if gcfg.draft_ratio is None:
        raise ValueError("build_tiered_masks needs GlassConfig(draft_ratio=...)")
    if slot_axis:
        def one(st):
            t, d = build_tiered_masks(st, global_prior, gcfg)
            return t.idx, t.mask, t.scores, d.idx, d.mask, d.scores

        ti, tm, ts, di, dm, ds = jax.vmap(one)(local_stats)
        mv = lambda a: jnp.moveaxis(a, 0, 1)
        return (
            MaskSet(idx=mv(ti), mask=mv(tm), scores=mv(ts)),
            MaskSet(idx=mv(di), mask=mv(dm), scores=mv(ds)),
        )
    ms = build_masks(local_stats, global_prior, gcfg)
    didx, dmask = select(ms.scores, gcfg.draft_config())
    return ms, MaskSet(idx=didx, mask=dmask, scores=ms.scores)


def reselect_at_density(ms: MaskSet, gcfg: GlassConfig, density: float) -> MaskSet:
    """Re-select from an existing MaskSet's fused scores at a different
    density — no stats or prior needed.  Because both selections rank the
    IDENTICAL scores with the same stable tie-break, the lower-density
    selection always NESTS inside the higher one (the
    :func:`build_tiered_masks` argument): the basis of per-request
    densities sharing one fixed-capacity slot arena."""
    didx, dmask = select(ms.scores, replace(gcfg, density=density, draft_ratio=None))
    return MaskSet(idx=didx, mask=dmask, scores=ms.scores)


def compact_params(model: Model, params, idx: jax.Array):
    """One-time gather of selected units into compact decode weights.

    Returns the ``compact_layers`` pytree accepted by ``model.decode_step``
    (stacked over layers, matching the scan layout).

    Slot-stacked idx from ``build_masks(..., slot_axis=True)`` — one extra
    axis after L (dense/ssm (L, B, k), MoE (L, B, E, k), hybrid (1, B, k)) —
    yields per-slot compact weights with the same extra axis after L, the
    layout the decode steps accept for continuous batching."""
    cfg = model.cfg

    def per_layer(one, base_ndim: int):
        fn = one
        if idx.ndim - 1 > base_ndim:  # slot axis rides between L and the gather dims
            fn = jax.vmap(one, in_axes=(None, 0))
        return jax.vmap(fn)

    if cfg.is_encoder_decoder:
        return per_layer(compact_ffn_params, 1)(params["dec_layers"]["ffn"], idx)
    if cfg.family == "moe":
        return per_layer(compact_moe_params, 2)(
            {k: params["layers"]["moe"][k] for k in params["layers"]["moe"]}, idx
        )
    if cfg.family == "ssm":
        cm = params["layers"]["cm"]

        def one(p, i):
            return {
                "mu": p["mu"],
                "wr": p["wr"],
                "wk": jnp.take(p["wk"], i, axis=1),
                "wv": jnp.take(p["wv"], i, axis=0),
            }

        return per_layer(one, 1)(cm, idx)
    if cfg.family == "hybrid":
        i = idx[0] if idx.ndim > 1 else idx  # drop the shared-block L=1 axis
        if i.ndim == 2:  # per-slot (B, k)
            return jax.vmap(compact_ffn_params, in_axes=(None, 0))(
                params["shared_attn"]["ffn"], i
            )
        return compact_ffn_params(params["shared_attn"]["ffn"], i)
    return per_layer(compact_ffn_params, 1)(params["layers"]["ffn"], idx)


def glass_pipeline_masks(
    model: Model,
    params,
    prefill_stats: Dict,
    global_prior: jax.Array,
    gcfg: GlassConfig,
):
    """Convenience: masks + compact params in one call."""
    masks = build_masks(prefill_stats, global_prior, gcfg)
    compact = compact_params(model, params, masks.idx)
    return masks, compact
