"""Neuron-importance estimation: local A^l and global A^g / I^g.

All statistics are *running sums* (sum_abs, count) so they can be merged
across micro-batches, hosts, and checkpoint shards; ``finalize`` turns them
into the expectation used for ranking.

I^g uses multiplicative gain probes: with h -> h * (1 + p) at p = 0,
dL/dp_j = h_j * dL/dh_j per token, so a single backward pass yields the
first-order Taylor impact |h_j delta_j| of Eq. (5-6).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model


def finalize(stats: Dict[str, jax.Array]) -> jax.Array:
    """(sum_abs, count) -> mean importance. Supports (L,m) and MoE (L,E,f)."""
    sum_abs, count = stats["sum_abs"], stats["count"]
    while count.ndim < sum_abs.ndim:
        count = count[..., None]
    return sum_abs / jnp.maximum(count, 1.0)


def merge(a: Optional[Dict], b: Dict) -> Dict:
    if a is None:
        return b
    return jax.tree.map(lambda x, y: x + y, a, b)


def local_stats_from_prefill(stats: Dict) -> Dict:
    """Prefill already returns the right structure; exposed for symmetry."""
    return stats


@partial(jax.jit, static_argnums=0)
def _activation_stats_batch(model: Model, params, batch) -> Dict:
    _, stats = model.logits_with_stats(params, batch)
    return stats


def global_activation_stats(model: Model, params, batches: Iterable[Dict]) -> Dict:
    """A^g sums over a corpus of teacher-forced batches."""
    acc = None
    for batch in batches:
        acc = merge(acc, jax.device_get(_activation_stats_batch(model, params, batch)))
    return jax.tree.map(jnp.asarray, acc)


@partial(jax.jit, static_argnums=0)
def _impact_stats_batch(model: Model, params, batch) -> Dict:
    B, S = batch["tokens"].shape
    probes = model.probe_zeros((B, S))
    g = jax.grad(lambda pr: model.loss_with_probes(params, pr, batch))(probes)
    # g: (L, B, S, m) = h * dL/dh per token; loss is mean-CE, rescale to sum
    n_tok = jnp.asarray(float(B * S), jnp.float32)
    sums = jnp.sum(jnp.abs(g) * n_tok, axis=(1, 2))  # (L, m)
    return {"sum_abs": sums, "count": jnp.full((g.shape[0],), float(B * S), jnp.float32)}


def global_impact_stats(model: Model, params, batches: Iterable[Dict]) -> Dict:
    """I^g sums (Taylor impact) over teacher-forced batches."""
    acc = None
    for batch in batches:
        acc = merge(acc, jax.device_get(_impact_stats_batch(model, params, batch)))
    return jax.tree.map(jnp.asarray, acc)
