"""Rank aggregation and critical-neuron selection (the heart of GLASS).

Implements the paper's Sec. 3.4 / App. A:

  * ``ranks_ascending`` — rank_up with stable deterministic tie-breaking by
    neuron index (rank 1 = least important, rank m = most important);
  * ``glass_scores``    — the weighted-Borda / Mallows-MAP consensus score
    GLASS_j = (1-lambda) R^l_j + lambda R^g_j;
  * selection modes:
      - ``neuron``         exact global top-k (paper-faithful)
      - ``block``          TPU-native: scores aggregated over blocks of
                           ``block_size`` consecutive units, top blocks kept
      - ``shard_balanced`` k/n_shards neurons per model-parallel shard so the
                           compaction gather stays shard-local

All selection functions return *sorted* index arrays (ascending) plus a
binary mask; sorted gathers are friendlier to TPU memory systems and make
results reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GlassConfig:
    density: float = 0.5  # fraction of FFN units kept
    lam: float = 0.5  # lambda: weight of the global rank
    variant: str = "I"  # "A" (activation) | "I" (impact) global prior
    selection: str = "neuron"  # neuron | block | shard_balanced
    block_size: int = 128
    n_shards: int = 1
    # Draft tier for self-speculative decode: the SAME fused scores selected
    # at density * draft_ratio.  Because both tiers rank the same scores with
    # the same stable tie-break, the draft selection is always a prefix of
    # the target's sorted order — draft units/blocks NEST inside the target
    # set, so block-sparse decode's active-block lists nest too.
    draft_ratio: Optional[float] = None  # None = no draft tier

    def __post_init__(self):
        if self.draft_ratio is not None and not (0.0 < self.draft_ratio <= 1.0):
            raise ValueError(f"draft_ratio must be in (0, 1], got {self.draft_ratio}")

    def k_of(self, m: int) -> int:
        return max(1, int(round(self.density * m)))

    def draft_config(self) -> "GlassConfig":
        """The draft tier as a standalone config (same selection machinery,
        ``density * draft_ratio`` units kept, no further nesting)."""
        if self.draft_ratio is None:
            raise ValueError("draft_config() needs draft_ratio set")
        return replace(self, density=self.density * self.draft_ratio, draft_ratio=None)


def ranks_ascending(scores: jax.Array, axis: int = -1) -> jax.Array:
    """rank_up: smallest value -> rank 1, ..., largest -> rank m.

    Ties broken deterministically by neuron index (lower index gets the lower
    rank), implemented with a stable argsort.  Returns f32 ranks.
    """
    order = jnp.argsort(scores, axis=axis, stable=True)
    inv = jnp.argsort(order, axis=axis, stable=True)  # position of j in order
    return (inv + 1).astype(jnp.float32)


def glass_scores(local: jax.Array, global_: jax.Array, lam: float) -> jax.Array:
    """Fused consensus score per unit; larger = more important.

    Monotone-invariant: both signals go through rank space first (Sec. 3.4).
    lam = 0 recovers GRIFFIN (local-only); lam = 1 the static global mask.
    """
    rl = ranks_ascending(local)
    rg = ranks_ascending(global_)
    return (1.0 - lam) * rl + lam * rg


def merge_stat_sums(a, b):
    """Additive merge of two running GLASS stat-sum pytrees (the chunked
    prefill invariant: per-token contributions are independent, so chunk
    stats combine by plain addition — ``{"sum_abs", "count"}`` leaves both).

    The fused mask depends only on the left-fold of this merge over the
    prompt's chunks, which is what makes a cached prefix resumable: a
    snapshot of the fold at a chunk boundary plus the remaining chunks
    reproduces the uncached fold bit-for-bit (same additions, same order).
    ``None`` is the empty element (no chunks yet)."""
    if a is None:
        return b
    if b is None:
        return a
    return jax.tree.map(lambda x, y: x + y, a, b)


def select_topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k with stable index tie-breaking.  scores (..., m).

    Returns (idx (..., k) int32 sorted ascending, mask (..., m) f32)."""
    order = jnp.argsort(-scores, axis=-1, stable=True)
    idx = jnp.sort(order[..., :k], axis=-1).astype(jnp.int32)
    m = scores.shape[-1]
    onehot = jax.nn.one_hot(idx, m, dtype=jnp.float32)  # (..., k, m)
    mask = jnp.sum(onehot, axis=-2)
    return idx, mask


def block_aggregate(scores: jax.Array, block_size: int) -> jax.Array:
    """Mean score per block of ``block_size`` consecutive units."""
    m = scores.shape[-1]
    assert m % block_size == 0, (m, block_size)
    return jnp.mean(scores.reshape(scores.shape[:-1] + (m // block_size, block_size)), axis=-1)


def select_blocks(scores: jax.Array, k: int, block_size: int) -> Tuple[jax.Array, jax.Array]:
    """Block-structured selection (TPU adaptation).

    Keeps ceil(k / block_size) highest-mean-score blocks.  Returns
    (block_idx (..., nb_keep) int32 sorted, mask (..., m) f32)."""
    m = scores.shape[-1]
    bsc = block_aggregate(scores, block_size)
    nb_keep = max(1, (k + block_size - 1) // block_size)
    bidx, bmask = select_topk(bsc, nb_keep)
    mask = jnp.repeat(bmask, block_size, axis=-1)
    return bidx, mask


def select_shard_balanced(
    scores: jax.Array, k: int, n_shards: int
) -> Tuple[jax.Array, jax.Array]:
    """k/n_shards per contiguous shard slice (model-parallel locality).

    scores (..., m) with m % n_shards == 0 and k % n_shards == 0 required.
    Returns (idx (..., k) int32 *global* indices sorted, mask (..., m))."""
    m = scores.shape[-1]
    assert m % n_shards == 0 and k % n_shards == 0, (m, k, n_shards)
    per = m // n_shards
    kper = k // n_shards
    sh = scores.reshape(scores.shape[:-1] + (n_shards, per))
    idx_l, mask_l = select_topk(sh, kper)  # (..., n_shards, kper) local indices
    offs = (jnp.arange(n_shards, dtype=jnp.int32) * per)[..., None]
    idx = (idx_l + offs).reshape(scores.shape[:-1] + (k,))
    mask = mask_l.reshape(scores.shape[:-1] + (m,))
    return idx, mask


def select(
    scores: jax.Array, gcfg: GlassConfig, m: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch on gcfg.selection. scores (..., m) -> (idx, mask)."""
    m = m if m is not None else scores.shape[-1]
    k = gcfg.k_of(m)
    if gcfg.selection == "neuron":
        return select_topk(scores, k)
    if gcfg.selection == "block":
        return select_blocks(scores, k, gcfg.block_size)
    if gcfg.selection == "shard_balanced":
        return select_shard_balanced(scores, k, gcfg.n_shards)
    raise ValueError(gcfg.selection)


def jaccard(mask_a: jax.Array, mask_b: jax.Array, axis: int = -1) -> jax.Array:
    """Jaccard similarity between binary masks along ``axis``."""
    a = mask_a > 0.5
    b = mask_b > 0.5
    inter = jnp.sum((a & b).astype(jnp.float32), axis=axis)
    union = jnp.sum((a | b).astype(jnp.float32), axis=axis)
    return inter / jnp.maximum(union, 1.0)
