from .fusion import GlassConfig, glass_scores, jaccard, ranks_ascending, select
from .glass import (
    MaskSet,
    build_masks,
    build_tiered_masks,
    compact_params,
    compute_global_prior,
)
from .nps import NPSConfig, nps_corpus, teacher_forced_batch

__all__ = [
    "GlassConfig", "MaskSet", "NPSConfig",
    "build_masks", "build_tiered_masks", "compact_params", "compute_global_prior",
    "glass_scores", "jaccard", "nps_corpus", "ranks_ascending", "select",
    "teacher_forced_batch",
]
