from .fusion import GlassConfig, glass_scores, jaccard, ranks_ascending, select
from .glass import (
    GlassParams,
    MaskSet,
    build_masks,
    build_tiered_masks,
    compact_params,
    compute_global_prior,
    reselect_at_density,
)
from .nps import NPSConfig, nps_corpus, teacher_forced_batch

__all__ = [
    "GlassConfig", "GlassParams", "MaskSet", "NPSConfig",
    "build_masks", "build_tiered_masks", "compact_params", "compute_global_prior",
    "glass_scores", "jaccard", "nps_corpus", "ranks_ascending",
    "reselect_at_density", "select", "teacher_forced_batch",
]
