"""Null-Prompt Stimulation (NPS) — paper Sec. 3.3 / App. B.3.

Generates sequences from the model itself conditioned only on a BOS token:

  * first ``hot_steps`` tokens: temperature ``hot_temp`` (1.5) + bigram
    repetition penalty, to maximize initial diversity;
  * afterwards: temperature 1.0, penalty off;
  * top-k = 20 filtering throughout.

The generated corpus is then replayed (teacher forcing, each self-generated
next token as the pseudo-label) to accumulate the global priors A^g / I^g.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..serve.sampling import bigram_init, bigram_penalize, bigram_update, sample


@dataclass(frozen=True)
class NPSConfig:
    n_seqs: int = 64  # paper: 1000 (scaled for CPU runs)
    seq_len: int = 128  # paper: 1024
    batch: int = 32  # generation micro-batch
    bos_id: int = 1
    top_k: int = 20
    hot_steps: int = 10
    hot_temp: float = 1.5
    temp: float = 1.0
    bigram_penalty: float = 8.0


def nps_generate_batch(
    model: Model, params, rng: jax.Array, npc: NPSConfig, batch: int
) -> jax.Array:
    """Generate (batch, seq_len) token ids with the NPS sampling schedule.

    The whole generation is one lax.scan over decode steps (jit-friendly)."""
    cfg = model.cfg
    V = cfg.vocab_size
    cache = model.init_cache(batch, npc.seq_len + 1)
    prev = jnp.full((batch,), npc.bos_id, jnp.int32)
    seen = bigram_init(batch, V)

    def step(carry, i):
        cache, prev, seen, rng = carry
        rng, krng = jax.random.split(rng)
        logits, cache = model.decode_step(params, prev[:, None], cache, i.astype(jnp.int32))
        logits = logits[:, 0].astype(jnp.float32)
        hot = i < npc.hot_steps
        logits = bigram_penalize(logits, seen, prev, npc.bigram_penalty, enabled=hot)
        temp = jnp.where(hot, npc.hot_temp, npc.temp)
        nxt = sample(krng, logits, temperature=temp, top_k=npc.top_k).astype(jnp.int32)
        seen = bigram_update(seen, prev, nxt)
        return (cache, nxt, seen, rng), nxt

    (_, _, _, _), toks = jax.lax.scan(
        step, (cache, prev, seen, rng), jnp.arange(npc.seq_len)
    )
    return toks.T  # (batch, seq_len)


def nps_corpus(model: Model, params, rng: jax.Array, npc: NPSConfig) -> jax.Array:
    """Full NPS corpus (n_seqs, seq_len), generated in micro-batches."""
    outs = []
    n_done = 0
    gen = jax.jit(partial(nps_generate_batch, model, npc=npc, batch=npc.batch))
    while n_done < npc.n_seqs:
        rng, sub = jax.random.split(rng)
        outs.append(gen(params, sub))
        n_done += npc.batch
    return jnp.concatenate(outs, axis=0)[: npc.n_seqs]


def teacher_forced_batch(tokens: jax.Array, bos_id: int) -> dict:
    """Replay batch: inputs are [BOS, t_0..t_{n-2}], labels are the sequence
    itself (each self-generated next token is its own pseudo-label)."""
    B = tokens.shape[0]
    bos = jnp.full((B, 1), bos_id, tokens.dtype)
    inp = jnp.concatenate([bos, tokens[:, :-1]], axis=1)
    return {"tokens": inp, "labels": tokens}
