"""Oracle diagnostics (paper Sec. 4.3 / App. C.1).

The oracle critical set for an input is the top-k units by *post-hoc*
decoding-time activation magnitude — unavailable to any practical method,
but the reference against which Local-Only / Global-Only / Global-Local
selection quality is measured (Jaccard similarity, Tab. 5 / Fig. 1).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model
from . import importance
from .fusion import GlassConfig, jaccard, select_topk


def activation_stats_over_region(
    model: Model, params, tokens: jax.Array, region_mask: jax.Array
) -> Dict:
    """A-signal sums restricted to region_mask (B, S) positions."""
    if model.cfg.is_encoder_decoder:
        raise NotImplementedError
    from ..models import transformer

    _, _, stats, _ = transformer.forward(
        params, tokens, model.cfg, collect_stats=True, stats_mask=region_mask
    )
    return stats


def oracle_masks(
    model: Model,
    params,
    full_tokens: jax.Array,  # (B, S) prompt + generated continuation
    prompt_len: int,
    density: float,
) -> Tuple[jax.Array, jax.Array]:
    """Oracle = top-k by decoding-time activation magnitude.

    Stats are accumulated only over positions >= prompt_len (the generated
    region).  Returns (idx (L,k), mask (L,m))."""
    B, S = full_tokens.shape
    region = (jnp.arange(S)[None, :] >= prompt_len).astype(jnp.float32)
    region = jnp.broadcast_to(region, (B, S))
    stats = activation_stats_over_region(model, params, full_tokens, region)
    a_dec = importance.finalize(stats)
    k = max(1, int(round(density * a_dec.shape[-1])))
    return select_topk(a_dec, k)


def jaccard_vs_oracle(mask: jax.Array, oracle_mask: jax.Array) -> Dict[str, jax.Array]:
    """Per-layer and aggregate Jaccard of a candidate mask set vs the oracle."""
    per_layer = jaccard(mask, oracle_mask)
    return {
        "per_layer": per_layer,
        "mean": jnp.mean(per_layer),
        "std": jnp.std(per_layer),
    }
