import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first backend init.  Everything else follows.
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # test hook (still pre-jax-init)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ASSIGNED, get_config
from ..core.fusion import GlassConfig
from ..models.api import build_model
from ..sharding.ctx import use_rules
from ..sharding.partition import Planner, _path_str
from ..train.optim import OptConfig, init_opt_state
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh
from .specs import (
    SHAPES,
    applicable_shapes,
    batch_specs,
    compact_config,
    decode_specs,
    param_specs,
    prior_spec,
)
from .steps import make_decode_step, make_glass_prefill, make_train_step

# Hardware model: TPU v5e
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

# per-arch training knobs (memory levers; see EXPERIMENTS.md SS Perf)
TRAIN_OVERRIDES = {
    "grok-1-314b": dict(grad_accum=16, fsdp=True),
    "dbrx-132b": dict(grad_accum=8, fsdp=True),
    "qwen2-vl-72b": dict(grad_accum=8, fsdp=True),
    "gemma2-27b": dict(grad_accum=4, fsdp=True),
    "gemma2-9b": dict(grad_accum=2, fsdp=False),
    "whisper-large-v3": dict(grad_accum=1, fsdp=False),
}
DEFAULT_TRAIN = dict(grad_accum=2, fsdp=False)

def model_flops_global(cfg, shape, kind: str, density: float | None) -> float:
    """6*N*D (train) / 2*N_active*D (inference), D = tokens processed."""
    n = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    dcfg = compact_config(cfg, density) if density else cfg
    return 2.0 * dcfg.n_active_params() * shape.batch


def analyze(compiled, meta: dict, n_devices: int) -> dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    # trip-count-aware HLO walk (raw cost_analysis counts scan bodies once —
    # see hlo_cost.py; raw numbers kept for reference under "xla_raw")
    hlo = analyze_hlo(txt)
    flops_dev = float(hlo.dot_flops)
    # HBM traffic model: allocator-true buffers — every argument byte read,
    # every output written, temps written+read once each.  The instruction-
    # level sum (hlo.traffic_bytes) massively overcounts on the CPU backend
    # (its fusion boundaries differ from TPU) and is kept as a diagnostic.
    bytes_dev = float(
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + 2 * ma.temp_size_in_bytes
    )
    coll_dev = float(hlo.collective_traffic)
    colls = {
        k: {"count": hlo.collective_counts.get(k, 0), "bytes": v}
        for k, v in hlo.collective_bytes.items()
    }
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        ),
    }
    mf_global = model_flops_global(
        meta["cfg_obj"], SHAPES[meta["shape"]], meta["kind"], meta.get("density")
    )
    mf_dev = mf_global / n_devices
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / LINK_BW,
    }
    bottleneck = max(terms, key=terms.get)
    rec = {
        **{k: v for k, v in meta.items() if k != "cfg_obj"},
        "n_devices": n_devices,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": colls,
        "memory": mem,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else None,
        "xla_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "instr_traffic_upper_bound": float(hlo.traffic_bytes),
        },
        "roofline_terms_s": terms,
        "bottleneck": bottleneck,
        "roofline_step_s": max(terms.values()),
        "fits_hbm_16g": mem["peak_bytes"] <= 16 * 1024**3,
        # CPU-backend caveat: bf16 dot operands are converted to f32 on the
        # host backend, inflating temp buffers ~2x vs TPU (native bf16 MXU).
        # argument_bytes (resident params/cache/opt state) is conversion-free.
        "memory_caveat": "temp_bytes includes CPU-only bf16->f32 dot-operand conversions",
    }
    return rec


def _opt_shardings(planner: Planner, pshapes, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(path, leaf):
        return NamedSharding(mesh, planner.opt_spec(_path_str(path), leaf.shape))

    mu = jax.tree_util.tree_map_with_path(one, pshapes)
    import copy

    from ..train.optim import OptState

    return OptState(step=NamedSharding(mesh, P()), mu=mu, nu=jax.tree.map(lambda s: s, mu))


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    density: float | None = 0.5,
    mode_override: dict | None = None,
):
    """Lower + compile one (arch x shape) cell on the given mesh.

    Returns (lowered, compiled, meta)."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    shape = SHAPES[shape_name]
    kind = shape.kind
    ov = dict(TRAIN_OVERRIDES.get(cfg.name, DEFAULT_TRAIN))
    if mode_override:
        ov.update(mode_override)
    meta = {
        "arch": cfg.name,
        "shape": shape_name,
        "kind": kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "density": density if kind != "train" else None,
        "overrides": {k: v for k, v in ov.items() if k in ("grad_accum", "fsdp")},
        "cfg_obj": cfg,
    }

    if ov.get("expert_replication"):
        cfg = cfg.replace(expert_replication=ov["expert_replication"])
    if ov.get("remat"):
        cfg = cfg.replace(remat=ov["remat"])
    meta["cfg_obj"] = cfg

    if kind == "train":
        model = build_model(cfg)
        planner = Planner(
            cfg, mesh, mode="train", fsdp=ov.get("fsdp", False), pure_dp=ov.get("pure_dp", False)
        )
        pshapes = param_specs(cfg)
        pshard = planner.params(pshapes)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        oshard = _opt_shardings(planner, pshapes, mesh)
        bshapes = batch_specs(cfg, shape)
        bshard = planner.data_shardings(bshapes)
        # grads accumulate in the optimizer-moment (ZeRO) sharding: additionally
        # data-sharded, so per-microbatch grad sync is a reduce-scatter instead
        # of a full all-reduce, and the f32 carry is 1/data_n the size.
        step = make_train_step(
            model, OptConfig(), grad_accum=ov.get("grad_accum", 1), grad_shardings=oshard.mu
        )
        rules = planner.activation_rules(shape.batch, seq_parallel=ov.get("seq_parallel", False))
        with mesh, use_rules(mesh, rules):
            lowered = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            ).lower(pshapes, oshapes, bshapes)
    elif kind == "prefill":
        model = build_model(cfg)
        planner = Planner(cfg, mesh, mode="prefill")
        model_n = mesh.shape.get("model", 1)
        pshapes = param_specs(cfg)
        pshard = planner.params(pshapes)
        bshapes = batch_specs(cfg, shape)
        bshard = planner.data_shardings(bshapes)
        gcfg = GlassConfig(density=density or 0.5, selection="shard_balanced", n_shards=model_n)
        prefill = make_glass_prefill(model, gcfg, max_len=shape.seq, mesh=mesh, model_shards=model_n)
        prshape = prior_spec(cfg)
        from jax.sharding import NamedSharding, PartitionSpec as P

        rules = planner.activation_rules(shape.batch)
        with mesh, use_rules(mesh, rules):
            lowered = jax.jit(
                prefill,
                in_shardings=(pshard, bshard, NamedSharding(mesh, P())),
            ).lower(pshapes, bshapes, prshape)
    else:  # decode
        dcfg = compact_config(cfg, density) if density else cfg
        model = build_model(dcfg)
        planner = Planner(dcfg, mesh, mode="decode")
        specs = decode_specs(cfg, shape, density)
        pshard = planner.params(specs["params"])
        cshard = planner.cache_shardings(specs["cache"])
        from jax.sharding import NamedSharding, PartitionSpec as P

        tok_shard = NamedSharding(
            mesh, P(planner.dp if shape.batch % planner.dp_n == 0 else None, None)
        )
        step = make_decode_step(model)
        rules = planner.activation_rules(shape.batch)
        with mesh, use_rules(mesh, rules):
            lowered = jax.jit(
                step,
                in_shardings=(pshard, cshard, tok_shard, NamedSharding(mesh, P())),
                out_shardings=(tok_shard, cshard),
                donate_argnums=(1,),
            ).lower(specs["params"], specs["cache"], specs["token"], specs["cache_len"])

    compiled = lowered.compile()
    return lowered, compiled, meta


def run_cell(arch, shape_name, mesh, out_dir: Path, **kw) -> dict:
    t0 = time.time()
    lowered, compiled, meta = lower_cell(arch, shape_name, mesh, **kw)
    n_dev = int(np.prod(mesh.devices.shape))
    rec = analyze(compiled, meta, n_dev)
    rec["compile_s"] = round(time.time() - t0, 1)
    mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / f"{rec['arch']}__{shape_name}__{mesh_tag}.json"
    fname.write_text(json.dumps(rec, indent=1, default=str))
    mem_gb = rec["memory"]["peak_bytes"] / 1024**3
    print(
        f"[dryrun] {rec['arch']:18s} {shape_name:12s} mesh={mesh_tag:10s} "
        f"mem/dev={mem_gb:6.2f}GiB flops/dev={rec['hlo_flops_per_device']:.3e} "
        f"bottleneck={rec['bottleneck']:12s} useful={rec['useful_flops_ratio'] or 0:.2f} "
        f"compile={rec['compile_s']}s",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape name (default: all applicable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--dense-baseline", action="store_true", help="decode without GLASS compaction")
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else ASSIGNED
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    density = None if args.dense_baseline else args.density
    failures = []
    for mesh in meshes:
        for arch in archs:
            cfg = get_config(arch)
            shapes = [args.shape] if args.shape else applicable_shapes(cfg)
            for shp in shapes:
                try:
                    run_cell(arch, shp, mesh, out_dir, density=density)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((arch, shp, str(e)))
                    print(f"[dryrun] FAIL {arch} {shp}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures")
        raise SystemExit(1)
    print("[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()
