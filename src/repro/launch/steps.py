"""Step builders: the jittable train / glass-prefill / decode programs.

These are the functions the dry-run lowers and the real launchers run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core.fusion import GlassConfig, glass_scores, select_shard_balanced
from ..core.importance import finalize
from ..models.api import Model
from ..sharding.dist_glass import (
    compact_ffn_sharded,
    compact_moe_sharded,
    compact_rwkv_cm_sharded,
    to_local_indices,
)
from ..train.optim import OptConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def make_train_step(
    model: Model,
    oc: OptConfig,
    grad_accum: int = 1,
    grad_shardings=None,  # pytree of NamedSharding like params: pins the f32
):  # grad-accum carry (otherwise SPMD may replicate it — 4 bytes/param!)
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    grad_accum > 1 scans over microbatches accumulating f32 grads — the
    standard memory lever for the big-model cells."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_shardings)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            grads = pin(grads)
        else:
            def resh(x):
                B = x.shape[0]
                return x.reshape(grad_accum, B // grad_accum, *x.shape[1:])

            mbs = jax.tree.map(resh, batch)
            g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def body(acc, mb):
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = pin(jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g))
                return acc, (l, met)

            gsum, (ls, mets) = jax.lax.scan(body, g0, mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = jnp.mean(ls)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), mets)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, oc)
        return new_params, new_opt, {**metrics, **om, "loss": loss}

    return train_step


# ---------------------------------------------------------------------------
# Serving: GLASS prefill (stats -> fusion -> shard-balanced compaction)
# ---------------------------------------------------------------------------


def _ffn_width(cfg) -> int:
    return cfg.d_ff


def make_glass_prefill(
    model: Model,
    gcfg: GlassConfig,
    max_len: int,
    mesh: Optional[Mesh] = None,
    model_shards: int = 1,
):
    """Returns prefill(params, inputs, global_prior) ->
    (last_logits, cache, compact_ffn).

    With a mesh, selection is shard-balanced over the model axis and the
    weight gather runs shard-locally under shard_map (no collectives); on a
    single device it falls back to the exact global top-k."""
    cfg = model.cfg
    m_width = _ffn_width(cfg)

    def prefill(params, inputs, global_prior):
        logits, cache, stats = model.prefill(params, inputs, max_len)
        local = finalize(stats)
        if local.ndim == 1:
            local = local[None]
        prior = global_prior if global_prior.ndim == local.ndim else global_prior[None]
        scores = glass_scores(local, prior, gcfg.lam)
        k = gcfg.k_of(scores.shape[-1])
        if model_shards > 1:
            idx, _ = select_shard_balanced(scores, k, model_shards)
            idx_local = to_local_indices(idx, scores.shape[-1], model_shards)
        else:
            from ..core.fusion import select_topk

            idx, _ = select_topk(scores, k)
            idx_local = idx[..., None, :]  # (L, 1, k)

        if mesh is not None and model_shards > 1:
            if cfg.family == "moe":
                compact = compact_moe_sharded(mesh, params["layers"]["moe"], idx_local)
            elif cfg.family == "ssm":
                compact = compact_rwkv_cm_sharded(mesh, params["layers"]["cm"], idx_local)
            elif cfg.family == "hybrid":
                ffn = {k2: v[None] for k2, v in params["shared_attn"]["ffn"].items()}
                compact = compact_ffn_sharded(mesh, ffn, idx_local)
                compact = {k2: v[0] for k2, v in compact.items()}
            elif cfg.is_encoder_decoder:
                compact = compact_ffn_sharded(mesh, params["dec_layers"]["ffn"], idx_local)
            else:
                compact = compact_ffn_sharded(mesh, params["layers"]["ffn"], idx_local)
        else:
            from ..core.glass import compact_params as _cp

            compact = _cp(model, params, idx)
        last = logits[:, -1]
        return last, cache, compact

    return prefill


# ---------------------------------------------------------------------------
# Serving: decode step (greedy for the dry-run; engine uses sampling)
# ---------------------------------------------------------------------------


def make_decode_step(model: Model, greedy: bool = True, attn_mode: str = "gather"):
    """decode(params, cache, token, cache_len) -> (next_token, cache).

    For GLASS steady-state decode, pass params whose FFN weights are the
    compact ones (built by glass-prefill) — the step code is identical.
    ``attn_mode="paged_pallas"`` runs the fused paged-attention kernel on
    the paged cache layout instead of the XLA gather reference."""

    def decode(params, cache, token, cache_len):
        logits, cache = model.decode_step(
            params, token, cache, cache_len, attn_mode=attn_mode
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return decode


def make_decode_step_sampled(model: Model, attn_mode: str = "gather"):
    """Per-slot sampled decode with the counter-based positional PRNG —
    the jittable program behind the per-request ``SamplingParams`` API.

    Returns ``decode(params, cache, token, cache_len, seeds, pos,
    temperature, top_k, greedy_mask[, top_p, min_p]) ->
    (next_token (B, 1), cache)``: row ``b`` draws token ``pos[b]`` of
    request ``seeds[b]``'s stream (``sample_positional`` keys on exactly
    that pair, so replaying a position regenerates the same token), or the
    argmax where ``greedy_mask`` is set.  All sampling inputs are traced
    (B,) vectors — one compiled program serves any mix of greedy and
    sampled requests.  ``top_p`` / ``min_p`` are optional trailing (B,)
    vectors (nucleus and min-p filtering; omitted = disabled) so existing
    9-argument callers lower the identical program as before."""
    from ..serve.sampling import sample_positional

    def decode(params, cache, token, cache_len, seeds, pos, temperature,
               top_k, greedy_mask, top_p=None, min_p=None):
        logits, cache = model.decode_step(
            params, token, cache, cache_len, attn_mode=attn_mode
        )
        lg = logits[:, -1].astype(jnp.float32)
        g = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        s = sample_positional(lg, seeds, pos, temperature, top_k,
                              top_p=top_p, min_p=min_p)
        nxt = jnp.where(greedy_mask, g, s).astype(jnp.int32)[:, None]
        return nxt, cache

    return decode


def make_decode_step_masked(model: Model, attn_mode: str = "gather"):
    """Masked decode (no compaction): GLASS as a multiplier mask — the jnp
    reference for the block-sparse kernel path."""

    def decode(params, cache, token, cache_len, ffn_masks):
        logits, cache = model.decode_step(
            params, token, cache, cache_len, ffn_masks=ffn_masks,
            attn_mode=attn_mode,
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return decode


def make_decode_step_block_sparse(model: Model, block_size: int, groups=None,
                                  attn_mode: str = "gather"):
    """Block-sparse decode: per-request active FFN block ids (from
    ``GlassConfig(selection="block")``) feed the pallas ``glass_ffn`` kernel
    directly — weights stay resident, only active (d x block_size) tiles are
    streamed.  ``block_idx`` is (L, nb_keep) shared or (L, B, nb_keep)
    per-slot (continuous batching).

    ``groups`` (a static tuple of sizes >= 2) lowers the *shared-list
    batched* variant the paged engine uses when several decode rows carry
    identical active-block lists: grouped rows run one shared-list kernel
    per group (weight tiles streamed once per group, not once per row) and
    the returned step takes an extra ``row_perm`` (B,) argument ordering
    rows group-major with singletons last."""

    if groups is None:
        def decode(params, cache, token, cache_len, block_idx):
            logits, cache = model.decode_step(
                params, token, cache, cache_len,
                ffn_block_idx=block_idx, ffn_block_size=block_size,
                attn_mode=attn_mode,
            )
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return nxt, cache

        return decode

    def decode_grouped(params, cache, token, cache_len, block_idx, row_perm):
        logits, cache = model.decode_step(
            params, token, cache, cache_len,
            ffn_block_idx=block_idx, ffn_block_size=block_size,
            ffn_groups=tuple(groups), ffn_row_perm=row_perm,
            attn_mode=attn_mode,
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return decode_grouped


def make_verify_step(model: Model, glass_mode: Optional[str] = None,
                     block_size: int = 128, parallel: bool = False,
                     attn_mode: str = "gather"):
    """Speculative-verify step builder: the TARGET tier checks all ``T``
    candidate positions of a draft in one jittable program.

    Returns ``verify(params, cache, tokens, cache_len[, tier])`` ->
    ``(greedy (B, T), cache)`` where ``tokens`` is ``[pending, d_1..d_k]``
    and ``greedy[:, j]`` is the target verdict ``t_j`` (accept the longest
    prefix with ``d_{j+1} == t_j``).  The ``tier`` argument matches
    ``glass_mode``: ``None`` serves dense, ``"masked"`` takes per-slot
    ``ffn_masks``, ``"compact"`` takes a compact-weight pytree,
    ``"block_sparse"`` takes active FFN block ids.  The DRAFT pass needs no
    new builder — the existing decode-step builders accept the draft
    tier's rows/masks unchanged (tiers share every layout, only ``k``
    differs).

    ``parallel=True`` lowers the one-forward T-position verify (attention
    families only — see :meth:`Model.verify_steps`); the verdicts and KV
    rows stay BIT-identical to the sequential scan."""
    if glass_mode not in (None, "masked", "compact", "block_sparse"):
        raise ValueError(glass_mode)
    common = dict(parallel=parallel, attn_mode=attn_mode)

    if glass_mode is None:
        def verify(params, cache, tokens, cache_len):
            return model.verify_steps(params, tokens, cache, cache_len, **common)

        return verify

    def verify_tiered(params, cache, tokens, cache_len, tier):
        kw = dict(common)
        if glass_mode == "masked":
            kw["ffn_masks"] = tier
        elif glass_mode == "compact":
            kw["compact_layers"] = tier
        else:
            kw["ffn_block_idx"] = tier
            kw["ffn_block_size"] = block_size
        return model.verify_steps(params, tokens, cache, cache_len, **kw)

    return verify_tiered


def make_chunked_prefill(model: Model, chunk_tokens: int,
                         attn_mode: str = "gather"):
    """Chunked-prefill step for the paged serving path: processes up to
    ``chunk_tokens`` prompt tokens against a paged cache + block table,
    returning merged-by-addition GLASS chunk stats (see
    ``Model.prefill_chunk``).  The dry-run lowers one chunk at the bound
    length; the engine jit-caches per observed (T, nb) signature."""

    def prefill_chunk(params, tokens, cache, cache_len, block_table):
        assert tokens.shape[1] <= chunk_tokens, (tokens.shape, chunk_tokens)
        return model.prefill_chunk(
            params, tokens, cache, cache_len, block_table=block_table,
            attn_mode=attn_mode,
        )

    return prefill_chunk


def make_resumed_prefill(model: Model, chunk_tokens: int,
                         attn_mode: str = "gather"):
    """Prefix-cache warm prefill: one chunk that CONTINUES a cached
    prefix's GLASS stat fold instead of starting a fresh one.

    Returns ``prefill_resumed(params, tokens, cache, cache_len, block_table,
    carry_stats) -> (logits, cache, merged_stats)`` where ``carry_stats``
    is a restored prefix-cache snapshot (the left-fold over the cached
    rows) and ``merged_stats = merge_stat_sums(carry, chunk)``.  Because
    the merge is the same addition the engine applies between cold chunks,
    lowering this program at the fork point reproduces the cold fold
    bit-for-bit — the jittable witness of the prefix-cache resume
    invariant, and what the dry-run lowers for warm-start serving."""
    from ..core.fusion import merge_stat_sums

    def prefill_resumed(params, tokens, cache, cache_len, block_table,
                        carry_stats):
        assert tokens.shape[1] <= chunk_tokens, (tokens.shape, chunk_tokens)
        logits, cache, stats = model.prefill_chunk(
            params, tokens, cache, cache_len, block_table=block_table,
            attn_mode=attn_mode,
        )
        return logits, cache, merge_stat_sums(carry_stats, stats)

    return prefill_resumed


# ---------------------------------------------------------------------------
# Replica placement (cluster serving)
# ---------------------------------------------------------------------------


def place_replica(tree, devices):
    """Commit a pytree (params / KV arenas / the GLASS prior) to one
    replica's device slice, so every program an engine jit-builds over it
    runs — and caches — on that slice.

    Committed inputs are what make N replicas' decode ticks
    dispatch-concurrent: jit follows the argument placement, so replica
    ``r``'s programs execute on its own devices while the host thread moves
    on to replica ``r+1``.  Placement is what isolates the compiled-program
    caches too — each engine already owns its own ``ProgramCache``
    registry, and distinct input devices give the underlying executables
    distinct homes.

    ``devices`` is a device list from :func:`~repro.launch.mesh
    .replica_slices` (the first device carries single-device replicas) or
    ``None`` for the default-device fallback (single-device test runs: all
    replicas share one device, correct but serialized)."""
    if devices is None:
        return tree
    dev = devices[0] if isinstance(devices, (list, tuple)) else devices
    return jax.device_put(tree, dev)
