"""Assigned input shapes and ShapeDtypeStruct builders for every cell.

Shapes (per the assignment):
  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> glass_prefill (mask build)
  decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1     -> serve_step, sub-quadratic
                                                  archs only (ssm / hybrid)

Whisper (enc-dec): seq_len is the *audio-frame* count seen by the encoder
(frontend stubbed to precomputed frame embeddings); decoder text length is
seq_len // 4.  Decode shapes drive the decoder with a seq-length self-cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from ..models.api import Model, build_model
from ..models.common import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC = ("ssm", "hybrid")


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC:
        out.append("long_500k")  # full-attention archs skip (DESIGN.md §6)
    return out


def compact_config(cfg: ModelConfig, density: float) -> ModelConfig:
    """Config whose FFN width equals the GLASS-compact width."""
    return cfg.replace(d_ff=int(round(cfg.d_ff * density)))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    """Training / prefill input batch."""
    B, S = shape.batch, shape.seq
    if cfg.is_encoder_decoder:
        text = max(S // 4, 8)
        out = {
            "frames": SDS((B, S, cfg.d_model), cfg.compute_dtype),
            "tokens": SDS((B, text), jnp.int32),
        }
        if shape.kind == "train":
            out["labels"] = SDS((B, text), jnp.int32)
        return out
    out = {"tokens": SDS((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = SDS((B, S), jnp.int32)
    return out


def param_specs(cfg: ModelConfig) -> dict:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    model = build_model(cfg)
    if cfg.is_encoder_decoder:
        L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        dt = cfg.compute_dtype
        t_enc = max(max_len // 4, 8)
        return {
            "k": SDS((L, batch, max_len, K, hd), dt),
            "v": SDS((L, batch, max_len, K, hd), dt),
            "xk": SDS((L, batch, t_enc, K, hd), dt),
            "xv": SDS((L, batch, t_enc, K, hd), dt),
        }
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, density: Optional[float]) -> dict:
    """Inputs for serve_step: (params[, compact], cache, token, cache_len)."""
    dcfg = compact_config(cfg, density) if density else cfg
    return {
        "params": param_specs(dcfg),
        "cache": cache_specs(dcfg, shape.batch, shape.seq),
        "token": SDS((shape.batch, 1), jnp.int32),
        "cache_len": SDS((), jnp.int32),
    }


def prior_spec(cfg: ModelConfig) -> SDS:
    """Global-prior input to glass_prefill."""
    if cfg.family == "moe":
        slots = cfg.n_experts * cfg.expert_replication
        return SDS((cfg.n_layers, slots, cfg.d_ff), jnp.float32)
    if cfg.family == "hybrid":
        return SDS((1, cfg.d_ff), jnp.float32)
    return SDS((cfg.n_layers, cfg.d_ff), jnp.float32)
