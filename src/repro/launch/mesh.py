"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run entrypoint must set XLA_FLAGS
before anything initializes the backend.
"""
from __future__ import annotations

import jax


def _make_auto_mesh(shape, axes):
    """Version-compat mesh constructor.

    ``jax.sharding.AxisType`` only exists on newer jax; older releases build
    Auto-typed meshes by default, so simply omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_auto_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host/CPU devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return _make_auto_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
