"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run entrypoint must set XLA_FLAGS
before anything initializes the backend.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host/CPU devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=auto)


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
