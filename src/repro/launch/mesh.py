"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run entrypoint must set XLA_FLAGS
before anything initializes the backend.
"""
from __future__ import annotations

import jax
import numpy as np


def _make_auto_mesh(shape, axes):
    """Version-compat mesh constructor.

    ``jax.sharding.AxisType`` only exists on newer jax; older releases build
    Auto-typed meshes by default, so simply omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_auto_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host/CPU devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return _make_auto_mesh((data, model), ("data", "model"))


def replica_slices(mesh, n_replicas: int):
    """Device slices for a replica-sharded serving cluster: replica ``r``
    gets the ``data``-axis slice ``r % data_size`` of ``mesh`` — a list of
    the devices spanning the remaining (``model``/``pod``) axes.  Replicas
    on distinct slices dispatch their device work concurrently; when
    ``n_replicas`` exceeds the data-axis size, slices wrap (replicas then
    share devices — still correct, just serialized)."""
    if "data" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'data' axis: {mesh.axis_names}")
    di = mesh.axis_names.index("data")
    # move the data axis to the front, flatten the rest into one slice axis
    dev = np.moveaxis(mesh.devices, di, 0)
    dev = dev.reshape(dev.shape[0], -1)
    return [list(dev[r % dev.shape[0]]) for r in range(n_replicas)]


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
