"""Trip-count-aware cost analysis over compiled HLO text.

Why this exists: XLA's HloCostAnalysis (``compiled.cost_analysis()``) visits
every computation once — a ``lax.scan`` over 46 layers contributes its body
cost a single time, so FLOPs / bytes / collective counts are undercounted by
the trip count.  All our models scan over layers (HLO-size discipline for the
80-layer dry-runs), so we parse the optimized HLO ourselves:

  * computations are split and instructions indexed (name -> shape);
  * ``while`` ops are mapped to their body/condition; the trip count is
    recovered from the largest s32 constant in the condition computation
    (scan counters run 0..N with an LT compare — validated against known
    layer counts in tests);
  * dot FLOPs: 2 * prod(result dims) * prod(contracting dims);
  * HBM traffic: per top-level instruction, result + operand bytes.
    Post-fusion HLO boundaries are materialization points, so this is a
    structural estimate of HBM round-trips (fusion internals stay on-chip);
    plumbing ops (tuple/gte/parameter/bitcast/constant) are free;
  * collectives: result bytes per op kind (all-reduce counted 2x for the
    ring's reduce+broadcast phases), scaled by enclosing trip counts.

Everything is recursive: cost(entry) = sum(inst) + trip * cost(while body)
+ cost(fusion bodies through ``calls=``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DT_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")

FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "copy-start", "copy-done",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        if m.group("dt") not in _DT_BYTES:
            continue
        dims = m.group("dims")
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
        total += n * _DT_BYTES[m.group("dt")]
    return total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    dims = m.group("dims")
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclass
class Instruction:
    name: str
    shape: str
    op: str
    args: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)


@dataclass
class CostReport:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0  # instruction-level upper bound (see module doc)
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostReport", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult

    @property
    def collective_traffic(self) -> float:
        # ring all-reduce moves ~2x payload (reduce-scatter + all-gather phase)
        return sum(
            (2.0 if k.startswith("all-reduce") else 1.0) * v
            for k, v in self.collective_bytes.items()
        )


def _balanced(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start] ('(')."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instruction(raw: str) -> Optional[Instruction]:
    s = raw.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or " = " not in s:
        return None
    name, rhs = s.split(" = ", 1)
    name = name.strip().lstrip("%")
    rhs = rhs.strip()
    # shape: either a tuple "(...)" or "dtype[dims]{layout}"
    if rhs.startswith("("):
        end = _balanced(rhs, 0)
        shape = rhs[:end]
        rest = rhs[end:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape = rhs[:sp]
        rest = rhs[sp + 1 :].strip()
    # op name up to '('
    par = rest.find("(")
    if par < 0:
        return None
    op = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    end = _balanced(rest, par)
    args = [a.strip() for a in _split_args(rest[par + 1 : end - 1])]
    attrs = rest[end:]
    return Instruction(name=name, shape=shape, op=op, args=args, attrs=attrs, line=raw)


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        # computation header: "%name (params) -> type {" possibly "ENTRY %..."
        if line.endswith("{") and ") -> " in line and " = " not in line:
            hdr = line.lstrip()
            if hdr.startswith("ENTRY "):
                hdr = hdr[6:]
            name = hdr.split(" ", 1)[0].lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            continue
        if cur is None:
            continue
        inst = _parse_instruction(raw)
        if inst:
            cur.instructions.append(inst)
    return comps


def _split_args(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


class HloCostModel:
    def __init__(self, txt: str):
        self.comps = parse_module(txt)
        self.shapes: Dict[str, str] = {}
        for comp in self.comps.values():
            for inst in comp.instructions:
                self.shapes[inst.name] = inst.shape
        self._memo: Dict[str, CostReport] = {}
        self._entry = self._find_entry(txt)

    def _find_entry(self, txt: str) -> str:
        m = re.search(r"^ENTRY\s+%?(?P<name>[\w\.\-]+)", txt, re.M)
        if m:
            return m.group("name")
        return next(iter(self.comps))

    # -- trip count ------------------------------------------------------

    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        names = {cond_name}
        # include fusions called from the condition
        for inst in comp.instructions:
            cm = re.search(r"calls=%?([\w\.\-]+)", inst.attrs)
            if cm:
                names.add(cm.group(1))
        for nm in names:
            c = self.comps.get(nm)
            if not c:
                continue
            for inst in c.instructions:
                if inst.op == "constant" and inst.shape.startswith("s32[]"):
                    vm = re.search(r"constant\((-?\d+)\)", inst.line)
                    if vm:
                        best = max(best, int(vm.group(1)))
        return best

    # -- per-instruction costs -------------------------------------------

    def _operand_shape(self, ref: str) -> str:
        ref = ref.strip()
        # Typed operand syntax carries the shape inline ("bf16[4,256]{1,0} %x");
        # untyped syntax ("%x") needs the definition-site lookup.
        if not ref.startswith("%") and _SHAPE_RE.search(ref):
            return ref
        name = ref.lstrip("%").split(" ")[0]
        return self.shapes.get(name, "")

    def _dot_flops(self, inst: Instruction) -> float:
        out_dims = shape_dims(inst.shape)
        lhs_shape = shape_dims(self._operand_shape(inst.args[0]))
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
        contract = 1
        if cm and lhs_shape:
            for idx in cm.group(1).split(","):
                if idx:
                    contract *= lhs_shape[int(idx)]
        return 2.0 * float(np.prod(out_dims) if out_dims else 0) * contract

    def _conv_flops(self, inst: Instruction) -> float:
        out_dims = shape_dims(inst.shape)
        rhs = shape_dims(self._operand_shape(inst.args[1])) if len(inst.args) > 1 else []
        kernel = float(np.prod(rhs[:-1])) if rhs else 1.0
        return 2.0 * float(np.prod(out_dims)) * kernel

    # -- recursion ---------------------------------------------------------

    def computation_cost(self, name: str) -> CostReport:
        if name in self._memo:
            return self._memo[name]
        rep = CostReport()
        self._memo[name] = rep  # break cycles defensively
        comp = self.comps.get(name)
        if comp is None:
            return rep
        for inst in comp.instructions:
            if inst.op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                trips = self.trip_count(cm.group(1)) if cm else 1
                if bm:
                    rep.add(self.computation_cost(bm.group(1)), mult=trips)
                continue
            if inst.op in ("conditional", "call", "fusion", "reduce", "sort", "map",
                           "reduce-window", "scatter", "select-and-scatter", "custom-call"):
                for cm2 in re.finditer(
                    r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-,% ]+)\}?", inst.attrs
                ):
                    for sub in cm2.group(1).replace("%", "").split(","):
                        sub = sub.strip()
                        if sub in self.comps:
                            rep.add(self.computation_cost(sub))
            if inst.op in FREE_OPS:
                continue
            if inst.op == "dot":
                rep.dot_flops += self._dot_flops(inst)
            elif inst.op in ("convolution",):
                rep.dot_flops += self._conv_flops(inst)
            if inst.op in COLLECTIVES:
                key = inst.op.replace("-start", "")
                b = shape_bytes(inst.shape)
                rep.collective_bytes[key] = rep.collective_bytes.get(key, 0.0) + b
                rep.collective_counts[key] = rep.collective_counts.get(key, 0.0) + 1
                rep.traffic_bytes += b
                continue
            if inst.op.endswith("-done"):
                continue
            # HBM traffic: result + operands (args that are tensor refs)
            b = shape_bytes(inst.shape)
            for a in inst.args:
                b += shape_bytes(self._operand_shape(a))
            rep.traffic_bytes += b
        return rep

    def entry_cost(self) -> CostReport:
        return self.computation_cost(self._entry)


def analyze_hlo(txt: str) -> CostReport:
    return HloCostModel(txt).entry_cost()
