"""Deterministic synthetic corpus: Zipf unigrams + Markov bigram structure.

Provides the training/eval text for every in-repo experiment (no external
data offline).  Two properties matter:

  * determinism — doc ``i`` is a pure function of (seed, i), so the resumable
    pipeline can restart mid-epoch bit-identically on any host layout;
  * learnable structure — a fixed random bigram transition over a Zipf word
    inventory gives a tiny LM something real to model, so activation
    statistics (and GLASS masks) are meaningful rather than uniform.

A "shifted" variant (different seed *and* different word inventory) stands in
for the external corpus in the NPS-vs-corpus ablation (paper Tab. 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List

import numpy as np

from .tokenizer import BOS_ID, EOS_ID, encode

_CONS = "bcdfghjklmnpqrstvwz"
_VOW = "aeiou"


def _word_inventory(rng: np.random.Generator, n_words: int) -> List[str]:
    words = set()
    while len(words) < n_words:
        syll = rng.integers(1, 4)
        w = "".join(
            _CONS[rng.integers(len(_CONS))] + _VOW[rng.integers(len(_VOW))]
            for _ in range(syll)
        )
        words.add(w)
    return sorted(words)


@dataclass(frozen=True)
class CorpusConfig:
    seed: int = 0
    n_words: int = 512
    zipf_a: float = 1.3
    branch: int = 12  # bigram out-degree
    doc_len_words: tuple = (20, 200)


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig = CorpusConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.words = _word_inventory(rng, cfg.n_words)
        # zipf-ish unigram weights over a random permutation of words
        ranks = rng.permutation(cfg.n_words) + 1
        self.uni = (1.0 / ranks**cfg.zipf_a)
        self.uni /= self.uni.sum()
        # sparse bigram transitions: each word -> `branch` successors
        self.succ = rng.integers(0, cfg.n_words, size=(cfg.n_words, cfg.branch))

    def document(self, index: int) -> str:
        rng = np.random.default_rng((self.cfg.seed + 1) * 1_000_003 + index)
        lo, hi = self.cfg.doc_len_words
        n = int(rng.integers(lo, hi))
        w = int(rng.choice(self.cfg.n_words, p=self.uni))
        out = [self.words[w]]
        for _ in range(n - 1):
            if rng.random() < 0.15:  # unigram reset (topic shift)
                w = int(rng.choice(self.cfg.n_words, p=self.uni))
            else:
                w = int(self.succ[w, rng.integers(self.cfg.branch)])
            out.append(self.words[w])
        return " ".join(out)

    def token_stream(self, start_doc: int = 0) -> Iterator[np.ndarray]:
        i = start_doc
        while True:
            yield encode(self.document(i), add_bos=True, add_eos=True)
            i += 1


def shifted_corpus(seed: int = 777) -> SyntheticCorpus:
    """The 'external corpus' for the NPS-vs-corpus prior ablation: different
    inventory and statistics from whatever the model was trained on."""
    return SyntheticCorpus(CorpusConfig(seed=seed, n_words=512, zipf_a=1.05, branch=4))


class MixtureCorpus:
    """Multi-domain corpus: documents round-robin across ``n_domains``
    sub-corpora with disjoint word inventories and different statistics.

    This is the regime where GLASS's local signal carries information the
    global prior cannot: a model trained on the mixture activates
    domain-specific FFN units, prompt-local statistics reveal the active
    domain, while the NPS prior averages across domains (like a diverse
    pretraining mix vs a specific request)."""

    def __init__(self, seed: int = 0, n_domains: int = 3):
        self.domains = [
            SyntheticCorpus(
                CorpusConfig(seed=seed * 101 + 17 * d, n_words=256, zipf_a=1.2 + 0.1 * d, branch=6 + 4 * d)
            )
            for d in range(n_domains)
        ]
        self.n_domains = n_domains

    def document(self, index: int) -> str:
        d = index % self.n_domains
        return self.domains[d].document(index // self.n_domains)

    def domain_document(self, domain: int, index: int) -> str:
        return self.domains[domain].document(index)
