"""Resumable, host-sharded packed-sequence pipeline.

Documents are packed back-to-back into fixed-length rows; state is a single
integer (next document index) per host shard, checkpointed alongside the
model so restarts are bit-identical.  Host h of H draws documents
h, h+H, 2h+H, ... — deterministic without coordination, the standard
per-host sharding for 1000-node data loading.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from .synthetic import SyntheticCorpus
from .tokenizer import PAD_ID


@dataclass
class PipelineState:
    next_doc: int
    carry: np.ndarray  # leftover tokens from the last packed document

    def to_dict(self) -> Dict:
        return {"next_doc": int(self.next_doc), "carry": self.carry.tolist()}

    @staticmethod
    def from_dict(d: Dict) -> "PipelineState":
        return PipelineState(int(d["next_doc"]), np.asarray(d["carry"], np.int32))


class PackedLM:
    """Packs documents into (batch, seq+1) rows -> tokens/labels batches."""

    def __init__(
        self,
        corpus: SyntheticCorpus,
        batch: int,
        seq: int,
        host_index: int = 0,
        host_count: int = 1,
        state: Optional[PipelineState] = None,
    ):
        self.corpus = corpus
        self.batch = batch
        self.seq = seq
        self.host_index = host_index
        self.host_count = host_count
        self.state = state or PipelineState(
            next_doc=host_index, carry=np.zeros((0,), np.int32)
        )

    def _fill_row(self) -> np.ndarray:
        need = self.seq + 1
        buf = self.state.carry
        while buf.shape[0] < need:
            doc = self.corpus.document(self.state.next_doc)
            from .tokenizer import encode

            ids = encode(doc, add_bos=True, add_eos=True)
            self.state.next_doc += self.host_count
            buf = np.concatenate([buf, ids])
        row, self.state.carry = buf[:need], buf[need:]
        return row

    def next_batch(self) -> Dict[str, np.ndarray]:
        rows = np.stack([self._fill_row() for _ in range(self.batch)])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
            "mask": (rows[:, 1:] != PAD_ID).astype(np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
