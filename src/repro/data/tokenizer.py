"""Byte-level tokenizer with reserved specials, mapped into any vocab size.

ids: 0 = PAD, 1 = BOS, 2 = EOS, 3..258 = bytes.  For models whose vocab is
larger than 259 the rest of the table is simply unused (harmless — the
embedding rows exist but are never indexed); this keeps one tokenizer
consistent across every assigned architecture.
"""
from __future__ import annotations

from typing import List

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
BYTE_OFFSET = 3
VOCAB_MIN = 259


def encode(text: str, add_bos: bool = True, add_eos: bool = False) -> np.ndarray:
    ids = [b + BYTE_OFFSET for b in text.encode("utf-8")]
    if add_bos:
        ids = [BOS_ID] + ids
    if add_eos:
        ids = ids + [EOS_ID]
    return np.asarray(ids, np.int32)


def decode(ids) -> str:
    bs = bytes(int(i) - BYTE_OFFSET for i in ids if int(i) >= BYTE_OFFSET)
    return bs.decode("utf-8", errors="replace")
