"""Version-compat shims for jax API drift.

The repo targets a range of jax releases: newer ones expose
``jax.shard_map(..., check_vma=...)`` while older ones only have
``jax.experimental.shard_map.shard_map(..., check_rep=...)``.  Keep every
such fork here so model/serving code stays clean.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the replication-check flag mapped per version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
