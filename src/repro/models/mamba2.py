"""Mamba2 (SSD) mixer — chunked state-space duality algorithm.

Implements the scalar-decay-per-head SSD form of Mamba2 (Dao & Gu 2024):

    h_t = exp(dt_t * a) h_{t-1} + dt_t * B_t (x) x_t        (per head)
    y_t = C_t . h_t + D * x_t

Training/prefill uses the chunkwise-parallel algorithm (quadratic within a
chunk, linear across chunks via a lax.scan state carry); decode is the O(1)
recurrence.  n_groups = 1 (B/C shared across heads), matching Zamba2.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .common import ModelConfig, dense_init, rms_norm

CHUNK = 256


def mamba_dims(cfg: ModelConfig):
    d_in = cfg.mamba_expand * cfg.d_model
    n_heads = d_in // cfg.mamba_headdim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, n_heads, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    """Projections are stored per-component (z / x / B / C / dt and separate
    depthwise convs for x, B, C) rather than as one fused ``in_proj`` so every
    tensor-parallel shard boundary is component-aligned — a fused projection
    would force resharding at each slice (see DESIGN.md sharding notes)."""
    d = cfg.d_model
    d_in, H, _ = mamba_dims(cfg)
    N, K = cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 10)
    return {
        "w_z": dense_init(ks[0], (d, d_in), dtype),
        "w_x": dense_init(ks[1], (d, d_in), dtype),
        "w_B": dense_init(ks[2], (d, N), dtype),
        "w_C": dense_init(ks[3], (d, N), dtype),
        "w_dt": dense_init(ks[4], (d, H), dtype),
        "conv_x_w": dense_init(ks[5], (d_in, K), dtype, fan_in=K),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_B_w": dense_init(ks[6], (N, K), dtype, fan_in=K),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C_w": dense_init(ks[7], (N, K), dtype, fan_in=K),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[8], (H,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(ks[9], (H,), jnp.float32, minval=1e-3, maxval=0.1)) - 1.0
        ),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(jax.random.fold_in(key, 11), (d_in, d), dtype, fan_in=d_in),
    }


def _causal_conv(xc, conv_w, conv_b, prev: Optional[jax.Array] = None):
    """Depthwise causal conv over time. xc (B,S,C), conv_w (C,K).

    prev (B, K-1, C): carried context (decode / chunked prefill).  Returns
    (y, new_prev).  Implemented as K shifted adds (no gather): cheap on TPU
    and sharding-transparent over the channel dim."""
    B, S, C = xc.shape
    K = conv_w.shape[1]
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), xc.dtype)
    xp = jnp.concatenate([prev, xc], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros((B, S, C), xc.dtype)
    for t in range(K):
        y = y + jax.lax.dynamic_slice_in_dim(xp, t, S, axis=1) * conv_w[:, t]
    y = y + conv_b
    new_prev = xp[:, S:, :] if K > 1 else prev
    return jax.nn.silu(y), new_prev


def _project_conv(p, x, cfg: ModelConfig, conv_prev=None):
    """Per-component projections + depthwise causal convs.

    Returns (z, xs, Bm, Cm, dt, conv_state) with conv_state a dict of
    per-component carries {"x": (B,K-1,d_in), "B": (B,K-1,N), "C": ...} —
    kept split so the x carry shards over the model axis while B/C stay
    replicated (they are shared across heads)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    dt = x @ p["w_dt"]
    cp = conv_prev or {}
    xs, nx = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"], cp.get("x"))
    Bm, nB = _causal_conv(Bm, p["conv_B_w"], p["conv_B_b"], cp.get("B"))
    Cm, nC = _causal_conv(Cm, p["conv_C_w"], p["conv_C_b"], cp.get("C"))
    return z, xs, Bm, Cm, dt, {"x": nx, "B": nB, "C": nC}


def ssd_chunked(xh, dt, a, Bm, Cm, init_state=None, chunk: int = CHUNK):
    """Chunkwise SSD.

    xh  (B,S,H,P)   head inputs
    dt  (B,S,H)     positive step sizes
    a   (H,)        negative per-head decay rates
    Bm  (B,S,N)     input matrix (shared across heads, n_groups=1)
    Cm  (B,S,N)     output matrix
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    n = S // Q
    f32 = jnp.float32

    dA = dt.astype(f32) * a.astype(f32)  # (B,S,H) log-decay per step, <= 0
    dA = dA.reshape(B, n, Q, H)
    xw = (xh.astype(f32) * dt.astype(f32)[..., None]).reshape(B, n, Q, H, P)
    Bc = Bm.astype(f32).reshape(B, n, Q, N)
    Cc = Cm.astype(f32).reshape(B, n, Q, N)

    cum = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk (B,n,Q,H)
    total = cum[:, :, -1, :]  # (B,n,H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j else 0  (decays <= 1)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,n,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bniN,bnjN->bnij", Cc, Bc)  # (B,n,Q,Q)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", scores, L, xw)

    # chunk-local states: S_chunk = sum_j exp(total - cum_j) * B_j (x) xw_j
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # (B,n,Q,H)
    s_chunk = jnp.einsum("bnjN,bnjh,bnjhp->bnhNp", Bc, decay_to_end, xw)

    # inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((B, H, N, P), f32)

    def step(state, inp):
        s_c, tot, c_c, b_full = inp  # per-chunk tensors, leading dim B
        y_in = jnp.einsum("bqN,bhNp,bqh->bqhp", c_c, state, jnp.exp(b_full))
        new_state = state * jnp.exp(tot)[:, :, None, None] + s_c
        return new_state, y_in

    # exp factor for inter contribution at position i: exp(cum_i) (decay from
    # chunk start to i applied to the incoming state)
    scan_in = (
        s_chunk.transpose(1, 0, 2, 3, 4),  # (n,B,H,N,P)
        total.transpose(1, 0, 2),  # (n,B,H)
        Cc.transpose(1, 0, 2, 3),  # (n,B,Q,N)
        cum.transpose(1, 0, 2, 3),  # (n,B,Q,H)
    )
    final_state, y_inter = jax.lax.scan(step, init_state, scan_in)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B,n,Q,H,P)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, final_state


def mamba2_forward(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    init_state=None,
    conv_prev=None,
    chunk: int = CHUNK,
):
    """Full-sequence mixer. Returns (y, (ssm_state, conv_state))."""
    B, S, d = x.shape
    d_in, H, _ = mamba_dims(cfg)
    P, N = cfg.mamba_headdim, cfg.ssm_state
    z, xs, Bm, Cm, dt, conv_state = _project_conv(p, x, cfg, conv_prev)
    xs = constrain(xs.reshape(B, S, H, P), "act_heads")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs, dt, a, Bm, Cm, init_state=init_state, chunk=min(chunk, S))
    state = constrain(state, "act_state")
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (state, conv_state)


def mamba2_decode(
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cfg: ModelConfig,
    *,
    ssm_state: jax.Array,  # (B, H, N, P) f32
    conv_state: jax.Array,  # (B, K-1, conv_dim)
):
    """O(1) single-token recurrence."""
    B = x.shape[0]
    d_in, H, _ = mamba_dims(cfg)
    P, N = cfg.mamba_headdim, cfg.ssm_state
    z, xs, Bm, Cm, dt, conv_state = _project_conv(p, x, cfg, conv_state)
    xs = xs[:, 0].reshape(B, H, P)
    Bm, Cm = Bm[:, 0], Cm[:, 0]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    upd = jnp.einsum("bN,bhp,bh->bhNp", Bm.astype(jnp.float32), xs.astype(jnp.float32), dt)
    ssm_state = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bN,bhNp->bhp", Cm.astype(jnp.float32), ssm_state)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (ssm_state, conv_state)
