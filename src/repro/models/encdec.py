"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, T_frames, d) directly to the encoder.
Encoder: sinusoidal positions, bidirectional attention, GELU (non-gated) FFN.
Decoder: learned positions, causal self-attention + cross-attention, GELU FFN.

GLASS targets the decoder FFNs (the decode-time hot path); the non-gated FFN
is the g_j = 1 branch of the paper's Eq. (3).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    attention_decode,
    attention_forward,
    cross_attention_forward,
    init_attention,
    write_cache_prefill,
)
from ..sharding.ctx import constrain
from .common import ModelConfig, dense_init, embed_init, layer_norm, maybe_remat
from .ffn import ffn_forward, ffn_forward_with_stats, init_ffn
from .transformer import cross_entropy


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn": init_attention(ks[0], cfg, dtype),
        "ffn": init_ffn(ks[1], cfg, dtype),
        "ln1": _ln_init(cfg.d_model, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "self_attn": init_attention(ks[0], cfg, dtype),
        "cross_attn": init_attention(ks[1], cfg, dtype),
        "ffn": init_ffn(ks[2], cfg, dtype),
        "ln1": _ln_init(cfg.d_model, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
        "ln3": _ln_init(cfg.d_model, dtype),
    }


def init_encdec(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.compute_dtype
    ks = jax.random.split(key, 6)
    ekeys = jnp.stack(jax.random.split(ks[0], cfg.n_enc_layers))
    dkeys = jnp.stack(jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype),
        "pos_dec": embed_init(ks[3], (cfg.max_positions, cfg.d_model), dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(ekeys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dkeys),
        "enc_ln": _ln_init(cfg.d_model, dtype),
        "dec_ln": _ln_init(cfg.d_model, dtype),
    }


def sinusoids(length: int, channels: int) -> np.ndarray:
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames (B, T, d) — post-frontend embeddings (stub)."""
    B, T, d = frames.shape
    x = frames + jnp.asarray(sinusoids(T, d), frames.dtype)[None]

    def body(x, lp):
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        x = x + attention_forward(lp["attn"], h, cfg, positions=None, causal=False)
        h2 = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        x = constrain(x + ffn_forward(lp["ffn"], h2, cfg), "act_btd")
        return x, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["enc_layers"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"], cfg.norm_eps)


def decode_full(
    params,
    tokens: jax.Array,  # (B, S)
    enc_out: jax.Array,  # (B, T, d)
    cfg: ModelConfig,
    *,
    ffn_masks=None,  # (L_dec, m)
    probes=None,
    collect_stats: bool = False,
    stats_mask=None,
    return_cache: bool = False,
):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_dec"][None, :S]
    L = cfg.n_layers
    have_mask = ffn_masks is not None
    have_probe = probes is not None
    mask_xs = ffn_masks if have_mask else jnp.zeros((L, 0))
    probe_xs = probes if have_probe else jnp.zeros((L, 0))

    def body(x, xs):
        lp, mask_l, probe_l = xs
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        sa = attention_forward(lp["self_attn"], h, cfg, positions=None, return_kv=return_cache)
        kv = None
        if return_cache:
            sa, kv = sa
        x = x + sa
        h2 = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        x = x + cross_attention_forward(lp["cross_attn"], h2, enc_out, cfg)
        h3 = layer_norm(x, lp["ln3"]["w"], lp["ln3"]["b"], cfg.norm_eps)
        stats = None
        if collect_stats:
            y, stats = ffn_forward_with_stats(lp["ffn"], h3, cfg, token_mask=stats_mask)
        else:
            y = ffn_forward(
                lp["ffn"],
                h3,
                cfg,
                mask=mask_l if have_mask else None,
                probe=probe_l if have_probe else None,
            )
        x = constrain(x + y, "act_btd")
        return x, (stats, kv)

    x, (stats, kvs) = jax.lax.scan(
        maybe_remat(body, cfg), x, (params["dec_layers"], mask_xs, probe_xs)
    )
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    logits = constrain(x @ params["embed"].T, "logits")
    return logits, stats, kvs


def encdec_loss(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    logits, _, _ = decode_full(params, batch["tokens"], enc_out, cfg)
    loss, n = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce": loss, "aux": jnp.float32(0.0), "tokens": n}


def cross_kv(params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute per-layer cross-attention K/V: (L, B, T, Kh, hd)."""
    B, T, _ = enc_out.shape

    def one(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        return k, v

    return jax.vmap(one)(params["dec_layers"])


def encdec_prefill(params, frames, tokens, cfg: ModelConfig, max_len: int):
    """Encode + decoder prefill. Returns (logits, cache, stats)."""
    enc_out = encode(params, frames, cfg)
    logits, stats, kvs = decode_full(params, tokens, enc_out, cfg, collect_stats=True, return_cache=True)
    B, S = tokens.shape
    dt = cfg.compute_dtype
    shape = (cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.head_dim)
    ck, cv = jnp.zeros(shape, dt), jnp.zeros(shape, dt)
    k, v = kvs
    ck, cv = jax.vmap(write_cache_prefill)(ck, cv, k, v)
    xk, xv = cross_kv(params, enc_out, cfg)
    return logits, {"k": ck, "v": cv, "xk": xk, "xv": xv}, stats


def encdec_decode_step(
    params,
    token,  # (B, 1)
    cache,
    cache_len,
    cfg: ModelConfig,
    *,
    ffn_masks=None,
    compact_layers=None,
):
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0) + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], cache_len, 1, axis=0
    )[None]
    L = cfg.n_layers
    have_mask = ffn_masks is not None
    have_comp = compact_layers is not None
    mask_xs = ffn_masks if have_mask else jnp.zeros((L, 0))
    comp_xs = compact_layers if have_comp else jnp.zeros((L, 0))

    def body(x, xs):
        lp, ck, cv, xk, xv, mask_l, comp_l = xs
        h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        a, ck, cv = attention_decode(
            lp["self_attn"], h, cfg, cache_k=ck, cache_v=cv, cache_len=cache_len
        )
        x = x + a
        h2 = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        # cross attention against precomputed enc K/V
        from .attention import _attend, _project_qkv  # reuse internals

        q, _, _ = _project_qkv(lp["cross_attn"], h2, cfg)
        mask = jnp.ones((B, 1, 1, 1, xk.shape[1]), bool)
        ca = _attend(q, xk, xv, cfg, mask) @ lp["cross_attn"]["wo"]
        x = x + ca
        h3 = layer_norm(x, lp["ln3"]["w"], lp["ln3"]["b"], cfg.norm_eps)
        fp = comp_l if have_comp else lp["ffn"]
        x = x + ffn_forward(fp, h3, cfg, mask=mask_l if have_mask else None)
        return x, (ck, cv)

    def body_wrap(x, xs):
        lp, ck, cv, xk, xv, mask_l, comp_l = xs
        return body(
            x,
            (lp, ck, cv, xk, xv, mask_l if have_mask else None, comp_l if have_comp else None),
        )

    x, (ck, cv) = jax.lax.scan(
        body_wrap,
        x,
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"], mask_xs, comp_xs),
    )
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return logits, dict(cache, k=ck, v=cv)
