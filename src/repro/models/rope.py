"""Rotary position embeddings: standard RoPE and Qwen2-VL style M-RoPE.

M-RoPE splits the head_dim rotary frequencies into (temporal, height, width)
sections, each driven by its own position id stream.  For text tokens all
three ids coincide, making M-RoPE degenerate to standard RoPE — the property
tests rely on this.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,) float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (..., S) int -> angles (..., S, head_dim//2) f32."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate x (..., S, H, D) by angles (..., S, D//2).

    Uses the "split halves" convention (llama): pairs are (x[..., :D/2],
    x[..., D/2:]).
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(
    positions: jax.Array,  # (3, ..., S) int — (t, h, w) id streams
    head_dim: int,
    theta: float,
    sections: Tuple[int, ...],  # in *half-dim* units, sum == head_dim // 2
) -> jax.Array:
    """Angles (..., S, head_dim//2): frequency bands are distributed
    round-robin style by section, matching Qwen2-VL (interleaved sections over
    the frequency axis, simplified to contiguous chunks of the inv-freq
    vector)."""
    assert positions.shape[0] == 3, "m-rope needs (t,h,w) position streams"
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)
    chunks = []
    start = 0
    for idx, sec in enumerate(sections):
        pos = positions[idx].astype(jnp.float32)  # (..., S)
        chunks.append(pos[..., None] * inv[start : start + sec])
        start += sec
    return jnp.concatenate(chunks, axis=-1)


def positions_default(batch: int, seq: int, offset: jax.Array | int = 0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def mrope_positions_text(batch: int, seq: int, offset: jax.Array | int = 0) -> jax.Array:
    """Text-only M-RoPE ids: all three streams equal."""
    pos = positions_default(batch, seq, offset)
    return jnp.broadcast_to(pos[None], (3, batch, seq))
