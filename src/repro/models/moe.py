"""Mixture-of-Experts FFN with two selectable execution strategies.

* ``dense``    — every expert computed for every token, combined with routing
                 weights.  Exact, simple; used as the reference and for tiny
                 CPU tests.  Wastes FLOPs proportional to E/k (this shows up
                 in the roofline MODEL_FLOPS/HLO_FLOPs column by design).
* ``dropping`` — GShard-style capacity-based dispatch via one-hot einsums,
                 scanned over sequence chunks so dispatch tensors stay small.
                 With the expert dim sharded over the ``data`` mesh axis the
                 dispatch einsum lowers to an all-to-all (classic DP+EP).

GLASS applies *per expert*: each expert's d_ff units are ranked with local
stats accumulated only over the tokens routed to that expert.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .common import ModelConfig, activation, dense_init
from .ffn import STATS_EPS, token_normalized_abs


def n_slots(cfg: ModelConfig) -> int:
    return cfg.n_experts * cfg.expert_replication


def init_moe(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    f = d_ff if d_ff is not None else cfg.d_ff
    d, E = cfg.d_model, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),  # router kept f32
        "w_up": dense_init(ks[1], (E, d, f), dtype, fan_in=d),
        "w_down": dense_init(ks[2], (E, f, d), dtype, fan_in=f),
    }
    if cfg.gated_ffn:
        p["w_gate"] = dense_init(ks[3], (E, d, f), dtype, fan_in=d)
    if cfg.expert_replication > 1:
        rep = cfg.expert_replication
        for k2 in ("w_up", "w_down", "w_gate"):
            if k2 in p:  # slot s serves logical expert s // rep
                p[k2] = jnp.repeat(p[k2], rep, axis=0)
    return p


def _slot_idx(idx: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Map logical expert ids (..., k) to replica slots by token parity."""
    rep = cfg.expert_replication
    if rep == 1:
        return idx
    # position parity along the token axis (axis -2 of (..., tokens, k))
    t = jax.lax.broadcasted_iota(jnp.int32, idx.shape, idx.ndim - 2)
    return idx * rep + (t % rep)


def router_topk(p, x, cfg: ModelConfig):
    """Returns (weights (..., k), idx (..., k), aux_loss scalar, probs (..., E))."""
    logits = (x.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + STATS_EPS)
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    E = cfg.n_experts
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=-2), axis=tuple(range(idx.ndim - 1))
    )
    p_e = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = E * jnp.sum(f_e * p_e)
    return weights, idx, aux, probs


def _expert_hidden(p, xe, cfg: ModelConfig):
    """xe (E, ..., d) -> h (E, ..., f), batched over the expert dim.

    Per-slot compact weights (continuous batching) carry a leading slot axis
    aligned with xe's batch axis: w (B, E, d, k), xe (E, B, S, d)."""
    act = activation(cfg.ffn_act)
    if p["w_up"].ndim == 4:
        up = jnp.einsum("ebsd,bedf->ebsf", xe, p["w_up"])
        if "w_gate" in p:
            return act(jnp.einsum("ebsd,bedf->ebsf", xe, p["w_gate"])) * up
        return act(up)
    up = jnp.einsum("e...d,edf->e...f", xe, p["w_up"])
    if "w_gate" in p:
        return act(jnp.einsum("e...d,edf->e...f", xe, p["w_gate"])) * up
    return act(up)


def moe_dense(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    mask: Optional[jax.Array] = None,  # (E, f)
    collect_stats: bool = False,
    stats_mask: Optional[jax.Array] = None,  # (B, S)
):
    """All-experts einsum. Returns (y, aux, stats|None)."""
    weights, idx, aux, _ = router_topk(p, x, cfg)
    idx = _slot_idx(idx, cfg)
    E = n_slots(cfg)
    # combine weights per expert slot: (B,S,E)
    comb = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32) * weights[..., None], axis=-2)
    xe = jnp.broadcast_to(x[None], (E,) + x.shape)
    h = _expert_hidden(p, xe, cfg)  # (E,B,S,f)
    stats = None
    if collect_stats:
        a = token_normalized_abs(h)  # (E,B,S,f)
        routed = (comb > 0).astype(jnp.float32)  # (B,S,E)
        if stats_mask is not None:
            routed = routed * stats_mask.astype(jnp.float32)[..., None]
        routed_e = jnp.moveaxis(routed, -1, 0)[..., None]  # (E,B,S,1)
        stats = {
            "sum_abs": jnp.sum((a * routed_e).reshape(E, -1, h.shape[-1]), axis=1),
            "count": jnp.sum(routed_e.reshape(E, -1), axis=1),
        }
    if mask is not None:
        if mask.ndim == 3:  # per-slot (B, E, f)
            h = h * jnp.moveaxis(mask, 0, 1)[:, :, None, :].astype(h.dtype)
        else:  # shared (E, f)
            h = h * mask[:, None, None, :].astype(h.dtype)
    if p["w_down"].ndim == 4:  # per-slot compact (B, E, k, d)
        ye = jnp.einsum("ebsf,befd->ebsd", h, p["w_down"])
    else:
        ye = jnp.einsum("ebsf,efd->ebsd", h, p["w_down"])
    y = jnp.einsum("ebsd,bse->bsd", ye, comb.astype(ye.dtype))
    return y, aux, stats


def moe_dropping(
    p: dict,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    *,
    mask: Optional[jax.Array] = None,  # (E, f)
    collect_stats: bool = False,
    stats_mask: Optional[jax.Array] = None,
):
    assert stats_mask is None, "stats_mask only supported by the dense strategy"
    """Capacity-based dispatch, scanned over sequence chunks.

    Per chunk of c tokens (per batch group): capacity
    C = ceil(c * k * capacity_factor / E); tokens beyond capacity are dropped
    (their FFN contribution is zero — residual passes through), as in GShard.
    """
    B, S, d = x.shape
    E, k = n_slots(cfg), cfg.n_experts_per_tok
    c = min(cfg.moe_chunk, S)
    n_chunks = math.ceil(S / c)
    pad = n_chunks * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    C = max(1, math.ceil(c * k * cfg.capacity_factor / E))

    xc = x.reshape(B, n_chunks, c, d).transpose(1, 0, 2, 3)  # (n, B, c, d)

    def chunk_fn(carry, xch):  # xch (B, c, d)
        weights, idx, aux, _ = router_topk(p, xch, cfg)  # (B,c,k)
        idx = _slot_idx(idx, cfg)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B,c,k,E)
        # position of each (token, slot) within its expert queue, chunk-local
        flat = onehot.reshape(B, c * k, E)
        pos = jnp.cumsum(flat, axis=1) - flat  # exclusive cumsum
        pos = pos.reshape(B, c, k, E)
        keep = (pos < C).astype(jnp.float32) * onehot
        # dispatch (B,c,E,C): scatter slot weights into capacity buckets
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)  # (B,c,k,E,C)
        disp = jnp.sum(keep[..., None] * pos_oh, axis=2)  # (B,c,E,C)
        combw = jnp.sum(
            (keep * weights[..., None])[..., None] * pos_oh, axis=2
        )  # (B,c,E,C)
        xe = constrain(jnp.einsum("bcEC,bcd->EbCd", disp.astype(xch.dtype), xch), "moe_expert")
        h = constrain(_expert_hidden(p, xe, cfg), "moe_hidden")  # (E,B,C,f)
        st = None
        if collect_stats:
            a = token_normalized_abs(h)
            occupied = jnp.sum(disp, axis=(1,)).transpose(1, 0, 2)  # (E,B,C)
            st = {
                "sum_abs": jnp.sum(a * occupied[..., None], axis=(1, 2)),
                "count": jnp.sum(occupied, axis=(1, 2)),
            }
        if mask is not None:
            if mask.ndim == 3:  # per-slot (B, E, f)
                h = h * jnp.moveaxis(mask, 0, 1)[:, :, None, :].astype(h.dtype)
            else:
                h = h * mask[:, None, None, :].astype(h.dtype)
        if p["w_down"].ndim == 4:  # per-slot compact (B, E, k, d)
            ye = jnp.einsum("EbCf,bEfd->EbCd", h, p["w_down"])
        else:
            ye = jnp.einsum("EbCf,Efd->EbCd", h, p["w_down"])
        y = jnp.einsum("EbCd,bcEC->bcd", ye, combw.astype(ye.dtype))
        return carry, (y, aux, st)

    _, (ys, auxs, stats) = jax.lax.scan(chunk_fn, 0.0, xc)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * c, d)[:, :S]
    aux = jnp.mean(auxs)
    if collect_stats:
        stats = {k_: jnp.sum(v, axis=0) for k_, v in stats.items()}
    else:
        stats = None
    return y, aux, stats


def moe_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mask: Optional[jax.Array] = None,
    collect_stats: bool = False,
    stats_mask: Optional[jax.Array] = None,
):
    if cfg.moe_strategy == "dense":
        return moe_dense(p, x, cfg, mask=mask, collect_stats=collect_stats, stats_mask=stats_mask)
    return moe_dropping(p, x, cfg, mask=mask, collect_stats=collect_stats, stats_mask=stats_mask)


def compact_moe_params(p: dict, idx: jax.Array) -> dict:
    """Per-expert compact gather. idx (E, k_keep) int32."""
    take = jax.vmap(lambda w, i: jnp.take(w, i, axis=1))
    out = {
        "router": p["router"],
        "w_up": take(p["w_up"], idx),
        "w_down": jax.vmap(lambda w, i: jnp.take(w, i, axis=0))(p["w_down"], idx),
    }
    if "w_gate" in p:
        out["w_gate"] = take(p["w_gate"], idx)
    return out
