"""RWKV6 ("Finch") — attention-free time-mix with data-dependent decay, plus
the squared-ReLU channel-mix FFN (the GLASS target in this family).

Per head (dim P), per channel-of-key decay w_t in (0,1):

    S_t = Diag(w_t) S_{t-1} + k_t v_t^T            S in R^{P x P}
    y_t = (S_{t-1} + Diag(u) k_t v_t^T)^T r_t

Training/prefill uses a chunkwise-parallel form in log-decay space (all
exponents <= 0, numerically safe); decode is the O(1) recurrence.

Simplifications vs the full Finch block (documented in DESIGN.md):
token-shift interpolation uses static per-channel mixing for r/k/v/g and the
data-dependent LoRA path only for the decay w — the architecture's defining
feature.  Output gating, per-head group-norm, and the u-bonus are faithful.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .common import ModelConfig, dense_init

CHUNK = 64
# Log-decay is clamped to [_W_FLOOR, _W_CLAMP].  The floor bounds the
# intra-chunk exponent |cum| <= CHUNK * |_W_FLOOR| = 32, keeping the factored
# chunk algorithm exact in f32 (exp(32) ~ 7.8e13 << f32 max) without any
# 6D safety tensor.  This is a modeling constraint (w >= exp(-0.5) ~ 0.61),
# documented in DESIGN.md; the sequential reference applies the same clamp.
_W_CLAMP = -1e-4
_W_FLOOR = -0.5


def rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_headdim


def init_time_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, r = cfg.d_model, cfg.rwkv_lora_rank
    H, P = rwkv_heads(cfg), cfg.rwkv_headdim
    ks = jax.random.split(key, 8)
    return {
        "mu": jax.random.uniform(ks[0], (4, d), jnp.float32),  # r,k,v,g static lerp
        "mu_w": jax.random.uniform(ks[1], (d,), jnp.float32),
        "w0": jnp.full((d,), -6.0, jnp.float32)
        + jax.random.uniform(ks[2], (d,), jnp.float32),
        "w_lora_a": dense_init(ks[3], (d, r), jnp.float32),
        "w_lora_b": jnp.zeros((r, d), jnp.float32),
        "u": (jax.random.uniform(ks[4], (H, P), jnp.float32) - 0.5),
        "wr": dense_init(ks[5], (d, d), dtype),
        "wk": dense_init(ks[6], (d, d), dtype),
        "wv": dense_init(ks[7], (d, d), dtype),
        "wg": dense_init(jax.random.fold_in(key, 101), (d, d), dtype),
        "wo": dense_init(jax.random.fold_in(key, 102), (d, d), dtype),
        "ln_w": jnp.ones((d,), jnp.float32),
        "ln_b": jnp.zeros((d,), jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32),  # k, r
        "wk": dense_init(ks[1], (d, f), dtype),
        "wv": dense_init(ks[2], (f, d), dtype, fan_in=f),
        "wr": dense_init(jax.random.fold_in(key, 7), (d, d), dtype),
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """x_{t-1} stream: (B,S,d) -> shifted (B,S,d); prev (B,d) is the carry."""
    B, S, d = x.shape
    if prev is None:
        prev = jnp.zeros((B, d), x.dtype)
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def _decay_log(p, xw: jax.Array) -> jax.Array:
    """Data-dependent log-decay in [_W_FLOOR, _W_CLAMP]. xw (B,S,d) f32."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.clip(-jnp.exp(p["w0"] + lora), _W_FLOOR, _W_CLAMP)


def wkv6_chunked(r, k, v, logw, u, init_state=None, chunk: int = CHUNK):
    """Chunkwise WKV6.

    r,k,v (B,S,H,P); logw (B,S,H,P) negative log-decays; u (H,P).
    Returns (y (B,S,H,P) f32, state (B,H,P,P) f32).  State layout: [key, value].
    """
    B, S, H, P = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    n = S // Q
    f32 = jnp.float32
    r, k, v, logw = (t.astype(f32).reshape(B, n, Q, H, P) for t in (r, k, v, logw))

    cum = jnp.cumsum(logw, axis=2)  # inclusive (B,n,Q,H,P)
    cum_prev = cum - logw  # exclusive: decay applied to state before step i
    total = cum[:, :, -1]  # (B,n,H,P)

    # intra-chunk scores: A[i,j] = (r_i * exp(cum_prev_i - cum_j)) . k_j, j < i.
    # Factored form: exp(cum_prev_i) <= 1 always; exp(-cum_j) <= exp(Q*|floor|)
    # = exp(32) which is f32-safe by the _W_FLOOR clamp (see module docstring).
    ri = r * jnp.exp(cum_prev)
    kj = k * jnp.exp(-cum)
    scores = jnp.einsum("bnihp,bnjhp->bnhij", ri, kj)
    tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bnihp,hp,bnihp->bnhi", r, u, k)  # u-bonus for j == i
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", scores, v) + (
        diag.transpose(0, 1, 3, 2)[..., None] * v
    )

    # chunk-state contribution of token j persisting to chunk end
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # (B,n,Q,H,P)
    s_chunk = jnp.einsum("bnjhp,bnjhq->bnhpq", k * decay_to_end, v)  # p=key,q=val

    if init_state is None:
        init_state = jnp.zeros((B, H, P, P), f32)

    def step(state, inp):
        s_c, tot, r_c, cp_c = inp
        y_in = jnp.einsum("bqhp,bhpv->bqhv", r_c * jnp.exp(cp_c), state)
        new_state = state * jnp.exp(tot)[..., None] + s_c
        return new_state, y_in

    scan_in = (
        s_chunk.transpose(1, 0, 2, 3, 4),
        total.transpose(1, 0, 2, 3),
        r.transpose(1, 0, 2, 3, 4),
        cum_prev.transpose(1, 0, 2, 3, 4),
    )
    state, y_inter = jax.lax.scan(step, init_state, scan_in)
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    return y.reshape(B, S, H, P), state


def _group_norm_heads(y: jax.Array, w, b, eps: float) -> jax.Array:
    """Per-head layer norm over P. y (B,S,H,P) f32; w/b (d,)."""
    B, S, H, P = y.shape
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(B, S, H * P)
    return yn * w + b


def time_mix_forward(
    p: dict,
    x: jax.Array,  # (B,S,d)
    cfg: ModelConfig,
    *,
    state=None,  # (B,H,P,P) f32
    shift_prev=None,  # (B,d)
    chunk: int = CHUNK,
):
    B, S, d = x.shape
    H, P = rwkv_heads(cfg), cfg.rwkv_headdim
    xs, new_shift = _shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xg = x + (xs - x) * mu[3]
    xw = (x + (xs - x) * p["mu_w"].astype(x.dtype)).astype(jnp.float32)
    r = constrain((xr @ p["wr"]).reshape(B, S, H, P), "act_heads")
    k = constrain((xk @ p["wk"]).reshape(B, S, H, P), "act_heads")
    v = constrain((xv @ p["wv"]).reshape(B, S, H, P), "act_heads")
    g = jax.nn.silu(xg @ p["wg"])
    logw = constrain(_decay_log(p, xw).reshape(B, S, H, P), "act_heads")
    y, new_state = wkv6_chunked(r, k, v, logw, p["u"], init_state=state, chunk=min(chunk, S))
    new_state = constrain(new_state, "act_state")
    y = _group_norm_heads(y, p["ln_w"], p["ln_b"], cfg.norm_eps)
    y = (y.astype(x.dtype) * g) @ p["wo"]
    return y, new_state, new_shift


def time_mix_decode(p, x, cfg: ModelConfig, *, state, shift_prev):
    """x (B,1,d). O(1) recurrence."""
    B, _, d = x.shape
    H, P = rwkv_heads(cfg), cfg.rwkv_headdim
    xs = shift_prev[:, None, :]
    mu = p["mu"].astype(x.dtype)
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xg = x + (xs - x) * mu[3]
    xw = (x + (xs - x) * p["mu_w"].astype(x.dtype)).astype(jnp.float32)
    r = (xr @ p["wr"]).reshape(B, H, P).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, H, P).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, H, P).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(_decay_log(p, xw).reshape(B, H, P))
    y = jnp.einsum("bhp,bhpv->bhv", r, state) + jnp.einsum(
        "bhp,hp,bhp,bhv->bhv", r, p["u"], k, v
    )
    new_state = state * w[..., None] + jnp.einsum("bhp,bhv->bhpv", k, v)
    y = _group_norm_heads(y[:, None], p["ln_w"], p["ln_b"], cfg.norm_eps)
    y = (y.astype(x.dtype) * g) @ p["wo"]
    return y, new_state, x[:, -1, :]


# ---------------------------------------------------------------------------
# Channel mix (the GLASS target: h = relu(xk Wk)^2, y = sigma(xr Wr) * (h Wv))
# ---------------------------------------------------------------------------


def channel_mix_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    shift_prev=None,
    mask: Optional[jax.Array] = None,
    probe: Optional[jax.Array] = None,
    collect_stats: bool = False,
    stats_mask: Optional[jax.Array] = None,  # (B, S)
):
    from .ffn import token_normalized_abs  # local import to avoid cycle

    xs, new_shift = _shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    if mu.ndim == 3:  # per-slot compact stack (B, 2, d) — continuous batching
        mu_k, mu_r = mu[:, 0:1], mu[:, 1:2]  # (B, 1, d)
    else:
        mu_k, mu_r = mu[0], mu[1]
    xk = x + (xs - x) * mu_k
    xr = x + (xs - x) * mu_r
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    if probe is not None:
        h = h * (1.0 + probe.astype(h.dtype))
    stats = None
    if collect_stats:
        a = token_normalized_abs(h)
        if stats_mask is not None:
            a = a * stats_mask.astype(jnp.float32)[..., None]
            count = jnp.sum(stats_mask.astype(jnp.float32))
        else:
            count = jnp.asarray(float(x.shape[0] * x.shape[1]), jnp.float32)
        stats = {
            "sum_abs": jnp.sum(a.reshape(-1, a.shape[-1]), axis=0),
            "count": count,
        }
    if mask is not None:
        h = h * mask.astype(h.dtype)
    y = jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])
    return y, new_shift, stats
