"""Decoder-only LM assembly for the dense / moe / vlm / ssm(rwkv6) / hybrid
(zamba2) families.

Layer parameters are stacked along a leading L axis and the stack is executed
with ``lax.scan`` (keeps HLO size O(1) in depth — essential for the 80-layer
dry-runs).  GLASS plumbing rides the scan:

  * ``ffn_masks``  (L, m) or (L, E, f)  — multiplier on FFN hidden units
  * ``probes``     (L, B, S, m)          — zeros; grad w.r.t. them = dL/dh
  * ``collect_stats``                    — emit per-layer |h|/||h||_2 sums

Gemma2-style local/global alternation is data-driven: a per-layer int32
``window`` rides the scan, so one body serves both layer kinds.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.ctx import constrain
from . import rwkv6 as rk
from .attention import (
    attention_decode,
    attention_decode_paged,
    attention_forward,
    init_attention,
    init_cache,
    write_cache_prefill,
)
from .common import (
    ModelConfig,
    dense_init,
    embed_init,
    layer_norm,
    maybe_remat,
    rms_norm,
    softcap,
)
from .ffn import ffn_forward, ffn_forward_with_stats, init_ffn
from .mamba2 import (
    init_mamba2,
    mamba2_decode,
    mamba2_forward,
    mamba_dims,
)
from .moe import init_moe, moe_forward
from .rope import mrope_positions_text, positions_default

GLOBAL_WINDOW = np.int32(2**30)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_dense_layer(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    p = {
        "attn": init_attention(ks[0], cfg, dtype),
        "ln1": jnp.zeros((d,), dtype) if cfg.sandwich_norms else jnp.ones((d,), dtype),
        "ln2": jnp.zeros((d,), dtype) if cfg.sandwich_norms else jnp.ones((d,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = init_ffn(ks[1], cfg, dtype)
    if cfg.sandwich_norms:
        p["ln1_post"] = jnp.zeros((d,), dtype)
        p["ln2_post"] = jnp.zeros((d,), dtype)
    return p


def _init_rwkv_layer(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "tm": rk.init_time_mix(ks[0], cfg, dtype),
        "cm": rk.init_channel_mix(ks[1], cfg, dtype),
        "ln1_w": jnp.ones((d,), dtype),
        "ln1_b": jnp.zeros((d,), dtype),
        "ln2_w": jnp.ones((d,), dtype),
        "ln2_b": jnp.zeros((d,), dtype),
    }


def _init_mamba_layer(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "mixer": init_mamba2(key, cfg, dtype),
        "ln": jnp.ones((cfg.d_model,), dtype),
    }


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, n_tail) — groups of mamba layers, each followed
    by one shared-attention-block invocation; tail mamba layers at the end."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = cfg.compute_dtype
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype)}

    if cfg.family in ("dense", "moe", "vlm"):
        lkeys = jnp.stack(jax.random.split(ks[1], cfg.n_layers))
        params["layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg, dtype))(lkeys)
        params["final_norm"] = (
            jnp.zeros((cfg.d_model,), dtype) if cfg.sandwich_norms else jnp.ones((cfg.d_model,), dtype)
        )
    elif cfg.family == "ssm":  # rwkv6
        lkeys = jnp.stack(jax.random.split(ks[1], cfg.n_layers))
        params["layers"] = jax.vmap(lambda k: _init_rwkv_layer(k, cfg, dtype))(lkeys)
        params["ln0_w"] = jnp.ones((cfg.d_model,), dtype)
        params["ln0_b"] = jnp.zeros((cfg.d_model,), dtype)
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
    elif cfg.family == "hybrid":  # zamba2
        n_groups, g, n_tail = hybrid_layout(cfg)
        gkeys = jax.random.split(ks[1], n_groups * g).reshape(n_groups, g)
        params["layers"] = jax.vmap(jax.vmap(lambda k: _init_mamba_layer(k, cfg, dtype)))(gkeys)
        if n_tail:
            tkeys = jnp.stack(jax.random.split(ks[2], n_tail))
            params["tail"] = jax.vmap(lambda k: _init_mamba_layer(k, cfg, dtype))(tkeys)
        params["shared_attn"] = _init_dense_layer(ks[3], cfg.replace(family="dense"), dtype)
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    else:
        raise ValueError(cfg.family)

    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab_size), dtype)
    return params


# ---------------------------------------------------------------------------
# Per-layer windows (gemma2 local/global alternation)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig) -> jax.Array:
    if cfg.attn_pattern == "local_global" and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else GLOBAL_WINDOW for i in range(cfg.n_layers)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * cfg.n_layers
    else:
        w = [GLOBAL_WINDOW] * cfg.n_layers
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return constrain(softcap(logits, cfg.logit_softcap), "logits")


def cross_entropy(
    logits: jax.Array,  # (..., V) any float dtype
    labels: jax.Array,  # (...,) int
    mask: Optional[jax.Array] = None,  # (...,) float
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over masked positions; stable f32 logsumexp. Returns (loss, n)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - lab
    if mask is not None:
        m = mask.astype(jnp.float32)
        n = jnp.maximum(jnp.sum(m), 1.0)
        return jnp.sum(nll * m) / n, n
    return jnp.mean(nll), jnp.asarray(float(nll.size), jnp.float32)


# ---------------------------------------------------------------------------
# Dense / MoE / VLM forward (full sequence)
# ---------------------------------------------------------------------------


def _dense_block(
    x,
    lp,
    cfg: ModelConfig,
    *,
    positions,
    window,
    mask_l=None,
    probe_l=None,
    collect_stats=False,
    stats_mask=None,
    return_kv=False,
):
    plus_one = cfg.sandwich_norms  # gemma-style (1+w) rmsnorm
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one)
    attn_out = attention_forward(
        lp["attn"], h, cfg, positions=positions, window=window, return_kv=return_kv
    )
    kv = None
    if return_kv:
        attn_out, kv = attn_out
    if cfg.sandwich_norms:
        attn_out = rms_norm(attn_out, lp["ln1_post"], cfg.norm_eps, True)
    x = x + attn_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one)
    aux = jnp.float32(0.0)
    stats = None
    if cfg.family == "moe":
        y, aux, stats = moe_forward(
            lp["moe"], h2, cfg, mask=mask_l, collect_stats=collect_stats, stats_mask=stats_mask
        )
    elif collect_stats:
        y, stats = ffn_forward_with_stats(lp["ffn"], h2, cfg, token_mask=stats_mask)
    else:
        y = ffn_forward(lp["ffn"], h2, cfg, mask=mask_l, probe=probe_l)
    if cfg.sandwich_norms:
        y = rms_norm(y, lp["ln2_post"], cfg.norm_eps, True)
    x = constrain(x + y, "act_btd")
    return x, aux, stats, kv


def dense_forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    ffn_masks=None,  # (L, m) or (L, E, f)
    probes=None,  # (L, B, S, m)
    collect_stats: bool = False,
    stats_mask=None,  # (B, S) float: restrict stats to these positions
    return_cache: bool = False,
    positions=None,
):
    """Full-sequence forward. Returns (logits, aux, stats, kv_stack)."""
    B, S = tokens.shape
    x = constrain(embed_tokens(params, tokens, cfg), "act_btd")
    if positions is None:
        positions = (
            mrope_positions_text(B, S) if cfg.rope_type == "mrope" else positions_default(B, S)
        )
    windows = layer_windows(cfg)

    def body(carry, xs):
        x = carry
        lp, window, mask_l, probe_l = xs
        x, aux, stats, kv = _dense_block(
            x,
            lp,
            cfg,
            positions=positions,
            window=window,
            mask_l=mask_l,
            probe_l=probe_l,
            collect_stats=collect_stats,
            stats_mask=stats_mask,
            return_kv=return_cache,
        )
        ys = (aux, stats, kv)
        return x, ys

    L = cfg.n_layers
    mask_xs = ffn_masks if ffn_masks is not None else jnp.zeros((L, 0))
    probe_xs = probes if probes is not None else jnp.zeros((L, 0))
    # normalize "absent" to None inside body via static flags:
    have_mask = ffn_masks is not None
    have_probe = probes is not None

    def body_wrap(carry, xs):
        lp, window, mask_l, probe_l = xs
        return body(
            carry,
            (lp, window, mask_l if have_mask else None, probe_l if have_probe else None),
        )

    scan_body = maybe_remat(body_wrap, cfg)
    x, (auxs, stats, kvs) = jax.lax.scan(
        scan_body, x, (params["layers"], windows, mask_xs, probe_xs)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.sandwich_norms)
    logits = lm_logits(params, x, cfg)
    return logits, jnp.sum(auxs) if auxs is not None else 0.0, stats, kvs


def dense_prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Prefill: logits + populated cache + GLASS local stats."""
    logits, _, stats, kvs = dense_forward(
        params, tokens, cfg, collect_stats=True, return_cache=True
    )
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len, cfg.n_layers, cfg.compute_dtype)
    k, v = kvs  # (L, B, S, K, hd)
    cache["k"], cache["v"] = jax.vmap(write_cache_prefill)(cache["k"], cache["v"], k, v)
    return logits, cache, stats


def dense_prefill_chunk(
    params, tokens, cfg: ModelConfig, cache, block_table, cache_len,
    attn_mode: str = "gather",
):
    """One chunk of an incremental (paged) prefill for dense/moe/vlm.

    tokens (B, T) continue a prompt whose first ``cache_len`` tokens already
    live in the paged cache {"k","v": (L, num_blocks, bs, K, hd)} through
    ``block_table`` (B, nb).  Positions are absolute (``cache_len + t``), so
    RoPE and sliding windows match the single-shot prefill exactly.  Returns
    (logits (B,T,V), cache, chunk_stats) — stats are *sums* over this
    chunk's tokens and merge across chunks by addition (importance.merge).
    """
    x = constrain(embed_tokens(params, tokens, cfg), "act_btd")
    windows = layer_windows(cfg)
    plus_one = cfg.sandwich_norms

    def body(x, xs):
        lp, ck, cv, window = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one)
        a, ck, cv = attention_decode_paged(
            lp["attn"], h, cfg, cache_k=ck, cache_v=cv,
            block_table=block_table, cache_len=cache_len, window=window,
            attn_mode=attn_mode,
        )
        if cfg.sandwich_norms:
            a = rms_norm(a, lp["ln1_post"], cfg.norm_eps, True)
        x = x + a
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one)
        if cfg.family == "moe":
            y, _, stats = moe_forward(lp["moe"], h2, cfg, collect_stats=True)
        else:
            y, stats = ffn_forward_with_stats(lp["ffn"], h2, cfg)
        if cfg.sandwich_norms:
            y = rms_norm(y, lp["ln2_post"], cfg.norm_eps, True)
        x = constrain(x + y, "act_btd")
        return x, (ck, cv, stats)

    x, (ck, cv, stats) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.sandwich_norms)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ck, "v": cv}, stats


def dense_decode_step(
    params,
    token,  # (B, T) int32: T = 1 decode tick, T > 1 parallel multi-token verify
    cache,  # {"k","v": (L,B,Smax,K,hd)}; paged: (L,num_blocks,bs,K,hd)
    cache_len,  # int32: scalar, or (B,) per-slot lengths (continuous batching)
    cfg: ModelConfig,
    *,
    ffn_masks=None,  # (L, m) shared, or (L, B, m) per-slot; MoE adds an E axis
    compact_layers=None,  # stacked compact FFN params (L-leading) replacing lp["ffn"];
    # per-slot serving stacks an extra slot axis after L, e.g. w_up (L, B, d, k)
    block_table=None,  # (B, nb) int32: paged-KV block table (BlockPool serving)
    ffn_block_idx=None,  # (L, nb_keep) shared or (L, B, nb_keep) per-slot active
    # FFN block ids -> block-sparse pallas kernel instead of dense masked matmuls
    ffn_block_size: int = 128,
    ffn_block_scale=None,  # (L, B, nb_keep) f32 per-(row, tile) contribution
    # multiplier (per-request density nested inside the capacity-tier lists;
    # 0.0 exactly zeroes a padding tile).  None = all tiles at full weight.
    ffn_groups=None,  # STATIC tuple of group sizes (each >= 2): rows whose
    # per-slot block lists are identical, batched through the shared-list
    # glass_ffn kernel; remaining rows run rowwise.  Requires ffn_row_perm.
    ffn_row_perm=None,  # (B,) int32: rows reordered group-major, singletons last
    attn_mode: str = "gather",
):
    """One decode step across all layers (scan). Returns (logits, new_cache).

    ``T > 1`` tokens run every position through one forward with the causal
    intra-chunk attention mask — the parallel speculative verify.  The
    block-sparse FFN then flattens the ``(B, T)`` grid to ``B*T`` rows
    (each slot's block list repeated per token) so the per-row kernels
    apply unchanged; ``T = 1`` keeps today's exact code path.
    """
    x = embed_tokens(params, token, cfg)
    windows = layer_windows(cfg)
    plus_one = cfg.sandwich_norms
    if ffn_block_idx is not None and cfg.family == "moe":
        raise NotImplementedError("block-sparse decode targets dense-FFN families")

    def body(x, xs):
        lp, ck, cv, window, mask_l, comp_l, bidx_l, bscale_l = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one)
        if block_table is not None:
            a, ck, cv = attention_decode_paged(
                lp["attn"], h, cfg, cache_k=ck, cache_v=cv,
                block_table=block_table, cache_len=cache_len, window=window,
                attn_mode=attn_mode,
            )
        else:
            a, ck, cv = attention_decode(
                lp["attn"], h, cfg, cache_k=ck, cache_v=cv, cache_len=cache_len, window=window
            )
        if cfg.sandwich_norms:
            a = rms_norm(a, lp["ln1_post"], cfg.norm_eps, True)
        x = x + a
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one)
        if cfg.family == "moe":
            mp = comp_l if comp_l is not None else lp["moe"]
            y, _, _ = moe_forward(mp, h2, cfg, mask=mask_l)
        elif bidx_l is not None:
            from ..kernels.ops import glass_ffn, glass_ffn_rowwise

            fp = lp["ffn"]
            B_, T_ = h2.shape[0], h2.shape[1]
            if bidx_l.ndim == 2 and ffn_groups:
                # shared-list batching: rows whose active-block lists are
                # identical share ONE grid over the list (weight tiles are
                # streamed once per group, not once per row); leftover
                # singleton rows take the rowwise kernel in a single call
                if T_ == 1:
                    xb, bi, bsc, perm = h2[:, 0], bidx_l, bscale_l, ffn_row_perm
                    groups = ffn_groups
                else:  # flatten (B, T) -> B*T rows, lists repeated per token
                    xb = h2.reshape(B_ * T_, -1)
                    bi = jnp.repeat(bidx_l, T_, axis=0)
                    bsc = None if bscale_l is None else jnp.repeat(bscale_l, T_, axis=0)
                    steps = jnp.arange(T_, dtype=ffn_row_perm.dtype)[None]
                    perm = (ffn_row_perm[:, None] * T_ + steps).reshape(-1)
                    groups = tuple(g * T_ for g in ffn_groups)
                xp = xb[perm]
                bp = bi[perm]
                sp = None if bsc is None else bsc[perm]
                parts = []
                off = 0
                for gs in groups:
                    parts.append(glass_ffn(
                        xp[off : off + gs], fp["w_up"], fp["w_down"],
                        bp[off], fp.get("w_gate"),
                        block_scale=None if sp is None else sp[off],
                        act=cfg.ffn_act, block_size=ffn_block_size,
                    ))
                    off += gs
                if off < xp.shape[0]:
                    parts.append(glass_ffn_rowwise(
                        xp[off:], fp["w_up"], fp["w_down"], bp[off:],
                        fp.get("w_gate"),
                        block_scale=None if sp is None else sp[off:],
                        act=cfg.ffn_act, block_size=ffn_block_size,
                    ))
                yp = jnp.concatenate(parts, axis=0)
                y32 = jnp.zeros_like(yp).at[perm].set(yp)
            else:
                per_row = bidx_l.ndim == 2
                kernel = glass_ffn_rowwise if per_row else glass_ffn
                if T_ == 1:
                    xb, bi, bsc = h2[:, 0], bidx_l, bscale_l
                else:
                    xb = h2.reshape(B_ * T_, -1)
                    bi = jnp.repeat(bidx_l, T_, axis=0) if per_row else bidx_l
                    bsc = (
                        None if bscale_l is None
                        else jnp.repeat(bscale_l, T_, axis=0) if per_row
                        else bscale_l
                    )
                y32 = kernel(
                    xb, fp["w_up"], fp["w_down"], bi, fp.get("w_gate"),
                    block_scale=bsc, act=cfg.ffn_act, block_size=ffn_block_size,
                )
            y = y32.astype(x.dtype).reshape(B_, T_, -1)
        else:
            fp = comp_l if comp_l is not None else lp["ffn"]
            if mask_l is not None and mask_l.ndim == 2:  # per-slot (B, m)
                mask_l = mask_l[:, None, :]
            y = ffn_forward(fp, h2, cfg, mask=mask_l)
        if cfg.sandwich_norms:
            y = rms_norm(y, lp["ln2_post"], cfg.norm_eps, True)
        x = x + y
        return x, (ck, cv)

    L = cfg.n_layers
    have_mask = ffn_masks is not None
    have_comp = compact_layers is not None
    have_bidx = ffn_block_idx is not None
    have_bscale = ffn_block_scale is not None
    mask_xs = ffn_masks if have_mask else jnp.zeros((L, 0))
    comp_xs = compact_layers if have_comp else jnp.zeros((L, 0))
    bidx_xs = ffn_block_idx if have_bidx else jnp.zeros((L, 0))
    bscale_xs = ffn_block_scale if have_bscale else jnp.zeros((L, 0))

    def body_wrap(x, xs):
        lp, ck, cv, window, mask_l, comp_l, bidx_l, bscale_l = xs
        return body(
            x,
            (lp, ck, cv, window, mask_l if have_mask else None,
             comp_l if have_comp else None, bidx_l if have_bidx else None,
             bscale_l if have_bscale else None),
        )

    x, (ck, cv) = jax.lax.scan(
        body_wrap, x,
        (params["layers"], cache["k"], cache["v"], windows, mask_xs, comp_xs,
         bidx_xs, bscale_xs),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.sandwich_norms)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# RWKV6 forward
# ---------------------------------------------------------------------------


def rwkv_forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    ffn_masks=None,
    probes=None,
    collect_stats=False,
    stats_mask=None,
    return_cache=False,
):
    B, S = tokens.shape
    x = constrain(embed_tokens(params, tokens, cfg), "act_btd")
    x = layer_norm(x, params["ln0_w"], params["ln0_b"], cfg.norm_eps)
    L = cfg.n_layers
    have_mask = ffn_masks is not None
    have_probe = probes is not None
    mask_xs = ffn_masks if have_mask else jnp.zeros((L, 0))
    probe_xs = probes if have_probe else jnp.zeros((L, 0))

    def body(x, xs):
        lp, mask_l, probe_l = xs
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps)
        y, state, shift_tm = rk.time_mix_forward(lp["tm"], h, cfg)
        x = x + y
        h2 = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        y2, shift_cm, stats = rk.channel_mix_forward(
            lp["cm"],
            h2,
            cfg,
            mask=mask_l if have_mask else None,
            probe=probe_l if have_probe else None,
            collect_stats=collect_stats,
            stats_mask=stats_mask,
        )
        x = constrain(x + y2, "act_btd")
        return x, (stats, (state, shift_tm, shift_cm) if return_cache else None)

    scan_body = maybe_remat(body, cfg)
    x, (stats, cache_parts) = jax.lax.scan(scan_body, x, (params["layers"], mask_xs, probe_xs))
    x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    cache = None
    if return_cache:
        state, shift_tm, shift_cm = cache_parts
        cache = {"state": state, "shift_tm": shift_tm, "shift_cm": shift_cm}
    return logits, jnp.float32(0.0), stats, cache


def rwkv_decode_step(params, token, cache, cache_len, cfg: ModelConfig, *, ffn_masks=None, compact_layers=None):
    x = embed_tokens(params, token, cfg)
    x = layer_norm(x, params["ln0_w"], params["ln0_b"], cfg.norm_eps)
    L = cfg.n_layers
    have_mask = ffn_masks is not None
    have_comp = compact_layers is not None
    mask_xs = ffn_masks if have_mask else jnp.zeros((L, 0))
    comp_xs = compact_layers if have_comp else jnp.zeros((L, 0))

    def body(x, xs):
        lp, state, sh_tm, sh_cm, mask_l, comp_l = xs
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps)
        y, state, sh_tm = rk.time_mix_decode(lp["tm"], h, cfg, state=state, shift_prev=sh_tm)
        x = x + y
        h2 = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        cm = comp_l if have_comp else lp["cm"]
        if have_mask and mask_l.ndim == 2:  # per-slot (B, m)
            mask_l = mask_l[:, None, :]
        y2, sh_cm, _ = rk.channel_mix_forward(
            cm, h2, cfg, shift_prev=sh_cm, mask=mask_l if have_mask else None
        )
        x = x + y2
        return x, (state, sh_tm, sh_cm)

    def body_wrap(x, xs):
        lp, state, sh_tm, sh_cm, mask_l, comp_l = xs
        return body(
            x,
            (lp, state, sh_tm, sh_cm, mask_l if have_mask else None, comp_l if have_comp else None),
        )

    x, (state, sh_tm, sh_cm) = jax.lax.scan(
        body_wrap,
        x,
        (params["layers"], cache["state"], cache["shift_tm"], cache["shift_cm"], mask_xs, comp_xs),
    )
    x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    return lm_logits(params, x, cfg), {"state": state, "shift_tm": sh_tm, "shift_cm": sh_cm}


def rwkv_prefill_chunk(params, tokens, cfg: ModelConfig, cache):
    """One chunk of an incremental rwkv6 prefill.

    The cache IS the recurrent state ({"state","shift_tm","shift_cm"}, rows
    for this request only), threaded through the chunkwise-parallel forward
    as initial carries; there are no KV rows to page.  Returns
    (logits (B,T,V), cache, chunk_stats)."""
    S = tokens.shape[1]
    x = constrain(embed_tokens(params, tokens, cfg), "act_btd")
    x = layer_norm(x, params["ln0_w"], params["ln0_b"], cfg.norm_eps)

    def body(x, xs):
        lp, st, sh_tm, sh_cm = xs
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps)
        # chunk=S: one wkv6 chunk per prefill chunk (T is engine-bounded, so
        # the intra-chunk quadratic term stays small)
        y, st, sh_tm = rk.time_mix_forward(lp["tm"], h, cfg, state=st, shift_prev=sh_tm, chunk=S)
        x = x + y
        h2 = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        y2, sh_cm, stats = rk.channel_mix_forward(
            lp["cm"], h2, cfg, shift_prev=sh_cm, collect_stats=True
        )
        x = constrain(x + y2, "act_btd")
        return x, (st, sh_tm, sh_cm, stats)

    x, (st, sh_tm, sh_cm, stats) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["shift_tm"], cache["shift_cm"])
    )
    x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    return logits, {"state": st, "shift_tm": sh_tm, "shift_cm": sh_cm}, stats


# ---------------------------------------------------------------------------
# Hybrid (zamba2): groups of mamba layers + shared attention block
# ---------------------------------------------------------------------------


def hybrid_forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    shared_mask=None,  # (m,) mask for the shared block's FFN
    collect_stats=False,
    stats_mask=None,
    return_cache=False,
):
    B, S = tokens.shape
    n_groups, g, n_tail = hybrid_layout(cfg)
    x = embed_tokens(params, tokens, cfg)
    positions = positions_default(B, S)
    sp = params["shared_attn"]

    def mamba_layer(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, (ssm, conv) = mamba2_forward(lp["mixer"], h, cfg)
        return constrain(x + y, "act_btd"), (ssm, conv)

    def group_body(x, xs):
        glp = xs
        x, states = jax.lax.scan(lambda c, lp: mamba_layer(c, lp), x, glp)
        # shared attention + FFN block (same params every group)
        x, aux, stats, kv = _dense_block(
            x,
            sp,
            cfg,
            positions=positions,
            window=None,
            mask_l=shared_mask,
            collect_stats=collect_stats,
            stats_mask=stats_mask,
            return_kv=return_cache,
        )
        return x, (states, stats, kv)

    scan_body = maybe_remat(group_body, cfg)
    x, (mstates, stats, kvs) = jax.lax.scan(scan_body, x, params["layers"])
    tail_states = None
    if n_tail:
        x, tail_states = jax.lax.scan(lambda c, lp: mamba_layer(c, lp), x, params["tail"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    if collect_stats and stats is not None:
        stats = {"sum_abs": jnp.sum(stats["sum_abs"], axis=0), "count": jnp.sum(stats["count"])}
    cache = None
    if return_cache:
        cache = {"mamba": mstates, "tail": tail_states, "kv": kvs}
    return logits, jnp.float32(0.0), stats, cache


def _conv_cache(cfg: ModelConfig, lead: tuple, batch: int):
    d_in, H, _ = mamba_dims(cfg)
    dt = cfg.compute_dtype
    K1 = cfg.ssm_conv - 1
    return {
        "x": jnp.zeros(lead + (batch, K1, d_in), dt),
        "B": jnp.zeros(lead + (batch, K1, cfg.ssm_state), dt),
        "C": jnp.zeros(lead + (batch, K1, cfg.ssm_state), dt),
    }


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, g, n_tail = hybrid_layout(cfg)
    d_in, H, _ = mamba_dims(cfg)
    P, N = cfg.mamba_headdim, cfg.ssm_state
    dt = cfg.compute_dtype
    cache = {
        "ssm": jnp.zeros((n_groups, g, batch, H, N, P), jnp.float32),
        "conv": _conv_cache(cfg, (n_groups, g), batch),
        "k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
    }
    if n_tail:
        cache["tail_ssm"] = jnp.zeros((n_tail, batch, H, N, P), jnp.float32)
        cache["tail_conv"] = _conv_cache(cfg, (n_tail,), batch)
    return cache


def hybrid_prefill(params, tokens, cfg: ModelConfig, max_len: int):
    logits, _, stats, raw = hybrid_forward(
        params, tokens, cfg, collect_stats=True, return_cache=True
    )
    B, S = tokens.shape
    cache = init_hybrid_cache(cfg, B, max_len)
    (ssm, conv) = raw["mamba"]
    cache["ssm"], cache["conv"] = ssm, conv
    k, v = raw["kv"]
    cache["k"], cache["v"] = jax.vmap(write_cache_prefill)(cache["k"], cache["v"], k, v)
    if raw["tail"] is not None:
        cache["tail_ssm"], cache["tail_conv"] = raw["tail"]
    return logits, cache, stats


def hybrid_decode_step(
    params, token, cache, cache_len, cfg: ModelConfig, *, shared_mask=None,
    shared_compact=None, block_table=None, attn_mode: str = "gather"
):
    n_groups, g, n_tail = hybrid_layout(cfg)
    x = embed_tokens(params, token, cfg)
    sp = params["shared_attn"]
    if shared_mask is not None and shared_mask.ndim == 2:  # per-slot (B, m)
        shared_mask = shared_mask[:, None, :]

    def mamba_step(x, lp, ssm, conv):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, (ssm, conv) = mamba2_decode(lp["mixer"], h, cfg, ssm_state=ssm, conv_state=conv)
        return x + y, ssm, conv

    def group_body(x, xs):
        glp, ssm_g, conv_g, ck, cv = xs

        def inner(c, inner_xs):
            lp, s, cv_ = inner_xs
            xx, s2, c2 = mamba_step(c, lp, s, cv_)
            return xx, (s2, c2)

        x, (ssm_g, conv_g) = jax.lax.scan(inner, x, (glp, ssm_g, conv_g))
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        if block_table is not None:
            a, ck, cv = attention_decode_paged(
                sp["attn"], h, cfg, cache_k=ck, cache_v=cv,
                block_table=block_table, cache_len=cache_len,
                attn_mode=attn_mode,
            )
        else:
            a, ck, cv = attention_decode(
                sp["attn"], h, cfg, cache_k=ck, cache_v=cv, cache_len=cache_len
            )
        x = x + a
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        fp = shared_compact if shared_compact is not None else sp["ffn"]
        y = ffn_forward(fp, h2, cfg, mask=shared_mask)
        x = x + y
        return x, (ssm_g, conv_g, ck, cv)

    x, (ssm, conv, ck, cv) = jax.lax.scan(
        group_body, x, (params["layers"], cache["ssm"], cache["conv"], cache["k"], cache["v"])
    )
    new_cache = dict(cache, ssm=ssm, conv=conv, k=ck, v=cv)
    if n_tail:
        def inner(c, inner_xs):
            lp, s, cv_ = inner_xs
            xx, s2, c2 = mamba_step(c, lp, s, cv_)
            return xx, (s2, c2)

        x, (tssm, tconv) = jax.lax.scan(
            inner, x, (params["tail"], cache["tail_ssm"], cache["tail_conv"])
        )
        new_cache["tail_ssm"], new_cache["tail_conv"] = tssm, tconv
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(params, x, cfg), new_cache


def hybrid_prefill_chunk(
    params, tokens, cfg: ModelConfig, cache, block_table, cache_len,
    attn_mode: str = "gather",
):
    """One chunk of an incremental hybrid (zamba2) prefill.

    Mamba layers thread their ssm/conv state rows as initial carries
    (``mamba2_forward(init_state, conv_prev)``); the shared attention block
    pages its KV through ``block_table`` like the dense path.  Returns
    (logits, cache, chunk_stats) with the shared block's stats aggregated
    over groups exactly as in :func:`hybrid_forward`."""
    n_groups, g, n_tail = hybrid_layout(cfg)
    T = tokens.shape[1]
    x = embed_tokens(params, tokens, cfg)
    sp = params["shared_attn"]

    def mamba_layer(x, lp, ssm0, conv0):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, (ssm, conv) = mamba2_forward(lp["mixer"], h, cfg, init_state=ssm0, conv_prev=conv0, chunk=T)
        return constrain(x + y, "act_btd"), ssm, conv

    def inner(c, ixs):
        lp, s0, c0 = ixs
        xx, s1, c1 = mamba_layer(c, lp, s0, c0)
        return xx, (s1, c1)

    def group_body(x, xs):
        glp, ssm_g, conv_g, ck, cv = xs
        x, (ssm_g, conv_g) = jax.lax.scan(inner, x, (glp, ssm_g, conv_g))
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        a, ck, cv = attention_decode_paged(
            sp["attn"], h, cfg, cache_k=ck, cache_v=cv,
            block_table=block_table, cache_len=cache_len,
            attn_mode=attn_mode,
        )
        x = x + a
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        y, stats = ffn_forward_with_stats(sp["ffn"], h2, cfg)
        x = constrain(x + y, "act_btd")
        return x, (ssm_g, conv_g, ck, cv, stats)

    x, (ssm, conv, ck, cv, stats) = jax.lax.scan(
        group_body, x, (params["layers"], cache["ssm"], cache["conv"], cache["k"], cache["v"])
    )
    new_cache = dict(cache, ssm=ssm, conv=conv, k=ck, v=cv)
    if n_tail:
        x, (tssm, tconv) = jax.lax.scan(
            inner, x, (params["tail"], cache["tail_ssm"], cache["tail_conv"])
        )
        new_cache["tail_ssm"], new_cache["tail_conv"] = tssm, tconv
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    stats = {"sum_abs": jnp.sum(stats["sum_abs"], axis=0), "count": jnp.sum(stats["count"])}
    return logits, new_cache, stats


# ---------------------------------------------------------------------------
# Uniform entry points
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg: ModelConfig, **kw):
    if cfg.family in ("dense", "moe", "vlm"):
        return dense_forward(params, tokens, cfg, **kw)
    if cfg.family == "ssm":
        return rwkv_forward(params, tokens, cfg, **kw)
    if cfg.family == "hybrid":
        kw.pop("probes", None)
        masks = kw.pop("ffn_masks", None)
        if masks is not None and masks.ndim > 1:
            masks = masks[0]
        return hybrid_forward(params, tokens, cfg, shared_mask=masks, **kw)
    raise ValueError(cfg.family)


def lm_loss(params, batch, cfg: ModelConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _, _ = forward(params, batch["tokens"], cfg)
    loss, n = cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + cfg.router_aux_weight * aux if cfg.family == "moe" else loss
    return total, {"ce": loss, "aux": aux, "tokens": n}
