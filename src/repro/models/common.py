"""Shared model-substrate pieces: config, norms, embeddings, init helpers.

Everything is pure JAX: params are nested dicts of jnp arrays, apply
functions are module-level and take the config explicitly.  Logical sharding
axes are attached out-of-band (see repro.sharding.logical) keyed by the param
tree path, so the model code stays sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    vocab_size: int = 512

    # FFN
    ffn_act: str = "silu"  # silu | gelu | relu | relu2
    gated_ffn: bool = True

    # attention extras
    rope_theta: float = 10000.0
    rope_type: str = "standard"  # standard | mrope | none
    mrope_sections: Tuple[int, ...] = ()
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    attn_pattern: str = "global"  # global | local_global (alternating, local first)
    sandwich_norms: bool = False  # gemma2 post-norms
    embed_scale: bool = False  # gemma: embeddings scaled by sqrt(d_model)
    # GQA execution layout (sharding-driven, numerics-identical):
    #   grouped  — q as (B,S,K,G,hd); best when kv_heads % model_parallel == 0
    #   repeated — q as (B,S,H,hd), kv broadcast to q heads; for kv_heads not
    #              divisible by the model axis but n_heads divisible
    gqa_layout: str = "grouped"

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_strategy: str = "dense"  # dense | dropping
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_chunk: int = 512  # sequence chunk for dropping dispatch
    # redundant-expert replication (DeepSeek-V3 style): expert weights are
    # stored as n_experts * expert_replication slots; the router still picks
    # logical experts, tokens split across replicas by position parity.
    # Lets an expert count that doesn't divide the data axis (grok: 8 vs 16)
    # run as clean expert parallelism instead of FSDP weight gathers.
    expert_replication: int = 1

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    mamba_headdim: int = 64
    mamba_expand: int = 2
    attn_every: int = 0  # zamba2: shared attention block every N mamba layers

    # rwkv6
    rwkv_headdim: int = 64
    rwkv_lora_rank: int = 32

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    max_positions: int = 8192  # learned-position table size for enc-dec

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: str = "full"  # none | dots | full
    # query-chunked attention kicks in above this seq length: peak score
    # memory drops from O(S^2) to O(attn_chunk * S) per head (exact, not an
    # approximation — full-row softmax per chunk)
    attn_chunk: int = 1024

    # GLASS integration defaults (density applied at serve time)
    glass_density: float = 0.5
    glass_block: int = 128  # block size for TPU block-structured selection

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND model-flops estimates)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
        ffn_mats = 3 if self.gated_ffn else 2
        if self.family == "moe":
            # logical parameter count (replicas are copies, not new params)
            ffn = self.n_experts * ffn_mats * d * f + d * self.n_experts
        else:
            ffn = ffn_mats * d * f
        if self.family == "ssm":  # rwkv6
            att_free = rwkv6_param_count(self)
            return v * d * (1 if self.tie_embeddings else 2) + L * att_free
        if self.family == "hybrid":
            return v * d + hybrid_param_count(self)
        if self.is_encoder_decoder:
            enc = self.n_enc_layers * (attn + ffn)
            dec = L * (2 * attn + ffn)  # self + cross attention
            return v * d * 2 + enc + dec
        embed = v * d * (1 if self.tie_embeddings else 2)
        return embed + L * (attn + ffn)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
        ffn_mats = 3 if self.gated_ffn else 2
        ffn_active = self.n_experts_per_tok * ffn_mats * d * f
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return embed + L * (attn + ffn_active)


def rwkv6_param_count(cfg: ModelConfig) -> int:
    d, f = cfg.d_model, cfg.d_ff
    tm = 4 * d * d + d * d  # r,k,v,g + output
    lora = 6 * 2 * d * cfg.rwkv_lora_rank
    cm = 2 * d * f  # channel mix: Wk (d,f), Wv (f,d) ; Wr (d,d)
    return tm + lora + cm + d * d


def hybrid_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    mamba = d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d  # rough
    shared_attn = 4 * d * d + 3 * d * cfg.d_ff
    return cfg.n_layers * mamba + shared_attn


# ---------------------------------------------------------------------------
# Common layers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float, plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32, cast back. ``plus_one``: gemma-style (1 + w) scale."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    scale = 1.0 + w if plus_one else w
    return (x * scale).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Sequence[int], dtype, fan_in: Optional[int] = None) -> jax.Array:
    """Truncated-normal init scaled by 1/sqrt(fan_in) (fan_in = shape[-2] default)."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Sequence[int], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def key_tree(key: jax.Array, n: int) -> list:
    return list(jax.random.split(key, n))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if cfg.remat == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(cfg))
