"""Gated FFN block with GLASS instrumentation hooks.

Naming follows the paper (Eq. 1-3) mapped to llama convention:

    h = phi(x @ w_gate) * (x @ w_up)        (gated)
    h = phi(x @ w_up)                        (non-gated, e.g. whisper GELU)
    y = h @ w_down

GLASS ranks the m hidden units h_j.  Hooks provided here:
  * ``mask``  — (m,) multiplier applied to h (neuron-level masking);
  * ``probe`` — *multiplicative gain probe*: h -> h * (1 + probe) with
    probe = 0, so grad(loss, probe) = h * dL/dh per token — exactly the
    I-GLASS first-order Taylor impact, in one backward pass;
  * ``stats`` — running sum of |h|/||h||_2 over tokens (the A^l / A^g signal).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .common import ModelConfig, activation, dense_init

STATS_EPS = 1e-6


def init_ffn(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> dict:
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, f), dtype),
        "w_down": dense_init(ks[1], (f, d), dtype, fan_in=f),
    }
    if cfg.gated_ffn:
        p["w_gate"] = dense_init(ks[2], (d, f), dtype)
    return p


def ffn_hidden(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Hidden unit vector h (..., m)."""
    act = activation(cfg.ffn_act)
    if "w_gate" in p:
        return act(x @ p["w_gate"]) * (x @ p["w_up"])
    return act(x @ p["w_up"])


def ffn_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    mask: Optional[jax.Array] = None,
    probe: Optional[jax.Array] = None,
) -> jax.Array:
    h = constrain(ffn_hidden(p, x, cfg), "act_btf")
    if probe is not None:
        h = h * (1.0 + probe.astype(h.dtype))
    if mask is not None:
        h = h * mask.astype(h.dtype)
    return h @ p["w_down"]


def token_normalized_abs(h: jax.Array) -> jax.Array:
    """|h|/(||h||_2 + eps) per token, f32. h (..., m) -> same shape f32."""
    h32 = h.astype(jnp.float32)
    nrm = jnp.sqrt(jnp.sum(jnp.square(h32), axis=-1, keepdims=True))
    return jnp.abs(h32) / (nrm + STATS_EPS)


def ffn_forward_with_stats(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    token_mask: Optional[jax.Array] = None,  # (..., ) 1.0 valid / 0.0 pad
) -> Tuple[jax.Array, dict]:
    """Forward pass that also emits GLASS local-importance sums.

    stats = {"sum_abs": (m,) f32 sum over tokens of |h|/||h||_2,
             "count":   ()   f32 number of tokens}
    """
    h = constrain(ffn_hidden(p, x, cfg), "act_btf")
    a = token_normalized_abs(h)
    if token_mask is not None:
        a = a * token_mask.astype(jnp.float32)[..., None]
        count = jnp.sum(token_mask.astype(jnp.float32))
    else:
        count = jnp.asarray(float(int(jnp.size(h) // h.shape[-1])), jnp.float32)
    sum_abs = jnp.sum(a.reshape(-1, a.shape[-1]), axis=0)
    y = h @ p["w_down"]
    return y, {"sum_abs": sum_abs, "count": count}


def compact_ffn_params(p: dict, idx: jax.Array) -> dict:
    """Gather the k selected hidden units into compact weights.

    idx (k,) int32 — columns of w_up/w_gate and rows of w_down.  This is the
    one-time gather after mask building; decode then runs dense matmuls of
    width k (the paper's "compact FFN resident in fast memory").
    """
    out = {
        "w_up": jnp.take(p["w_up"], idx, axis=1),
        "w_down": jnp.take(p["w_down"], idx, axis=0),
    }
    if "w_gate" in p:
        out["w_gate"] = jnp.take(p["w_gate"], idx, axis=1)
    return out
