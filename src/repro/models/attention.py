"""Grouped-query attention with RoPE / M-RoPE, score softcap, sliding window,
and KV-cache decode.

Conventions:
  x            (B, S, d_model)
  q            (B, S, K, G, hd)   K = kv heads, G = q_per_kv
  k, v         (B, S, K, hd)
  cache        dict(k=(B, S_max, K, hd), v=(B, S_max, K, hd))

The sliding ``window`` is a *traced* int32 scalar so that a single scan body
serves both local and global layers (gemma2 alternation): global layers pass
window = S_max (no-op).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.ctx import constrain
from .common import ModelConfig, dense_init, softcap
from .rope import apply_rope, mrope_angles, rope_angles

NEG_INF = -2.0e38  # f32-safe large negative


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": dense_init(ks[0], (d, cfg.attn_dim), dtype),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.attn_dim, d), dtype, fan_in=cfg.attn_dim),
    }


def _project_qkv(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    K, G, hd = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    if cfg.gqa_layout == "repeated":
        q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    else:
        q = (x @ p["wq"]).reshape(B, S, K, G, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    return q, k, v


def _rope_qk(q, k, cfg: ModelConfig, positions):
    """positions: (B,S) for standard rope, (3,B,S) for m-rope, None to skip."""
    if cfg.rope_type == "none" or positions is None:
        return q, k
    if cfg.rope_type == "mrope":
        ang = mrope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    else:
        ang = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    if q.ndim == 4:  # repeated layout: (B,S,H,hd)
        q = apply_rope(q, ang)
    else:  # grouped layout: fold (K, G) -> heads for rotation, then back.
        B, S, K, G, hd = q.shape
        q = apply_rope(q.reshape(B, S, K * G, hd), ang).reshape(B, S, K, G, hd)
    k = apply_rope(k, ang)
    return q, k


def _attend(q, k, v, cfg: ModelConfig, mask) -> jax.Array:
    """Scores in f32, optional tanh softcap. Returns (B, Sq, attn_dim).

    grouped layout:  q (B,Sq,K,G,hd), k/v (B,Skv,K,hd)
    repeated layout: q (B,Sq,H,hd),   k/v broadcast to H heads
    mask (B,1,1,Sq,Skv) broadcastable (grouped adds a G axis internally).
    """
    scale = cfg.head_dim ** -0.5
    if q.ndim == 4:  # repeated
        G = cfg.q_per_kv
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        scores = (
            jnp.einsum("bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32) * scale
        )
        if cfg.attn_softcap is not None:
            scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
        scores = jnp.where(mask[:, 0], scores, NEG_INF)  # (B,H,Sq,Skv)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
        B, Sq = out.shape[0], out.shape[1]
        return out.reshape(B, Sq, cfg.attn_dim)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    if cfg.attn_softcap is not None:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    B, Sq = out.shape[0], out.shape[1]
    return out.reshape(B, Sq, cfg.attn_dim)


def causal_window_mask(
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (Skv,) int32
    window,  # traced scalar or python int; None => no window
    kv_len=None,  # traced scalar or (B,): only positions < kv_len are valid
    causal: bool = True,
) -> jax.Array:
    """Boolean mask (B, 1, 1, Sq, Skv): True = attend."""
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[None, None, None, None, :]
    mask = jnp.ones(qp.shape[:4] + (kv_pos.shape[0],), dtype=bool)
    if causal:
        mask = qp >= kp
    if window is not None:
        mask = mask & ((qp - kp) < window)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim:  # per-slot lengths (continuous batching)
            kv_len = kv_len[:, None, None, None, None]
        mask = mask & (kp < kv_len)
    return mask


def _attend_chunked(q, k, v, cfg: ModelConfig, qpos, kvpos, window, causal, chunk):
    """Query-chunked attention: lax.scan over q chunks, exact full-row softmax
    per chunk.  Peak score memory O(chunk * S_kv) instead of O(S^2)."""
    B, S = q.shape[0], q.shape[1]
    nq = S // chunk
    assert S % chunk == 0, (S, chunk)
    q_c = jnp.moveaxis(q.reshape((B, nq, chunk) + q.shape[2:]), 1, 0)
    qpos_c = jnp.moveaxis(qpos.reshape(B, nq, chunk), 1, 0)

    @jax.checkpoint  # recompute per-chunk scores in backward: keeps the
    def body(_, xs):  # inner scan's residuals O(chunk) instead of O(S^2)
        qc, qp = xs
        mask = causal_window_mask(qp, kvpos, window, causal=causal)
        return 0, _attend(qc, k, v, cfg, mask)

    _, out = jax.lax.scan(body, 0, (q_c, qpos_c))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, cfg.attn_dim)


def attention_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions,
    window=None,
    causal: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _rope_qk(q, k, cfg, positions)
    q = constrain(q, "act_heads")
    k = constrain(k, "act_kv")
    v = constrain(v, "act_kv")
    S = x.shape[1]
    qpos = positions[0] if cfg.rope_type == "mrope" and positions is not None else positions
    if qpos is None:
        qpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (x.shape[0], S))
    kvpos = jnp.arange(S, dtype=jnp.int32)
    if S > 2 * cfg.attn_chunk and S % cfg.attn_chunk == 0:
        out = _attend_chunked(q, k, v, cfg, qpos, kvpos, window, causal, cfg.attn_chunk)
    else:
        mask = causal_window_mask(qpos, kvpos, window, causal=causal)
        out = _attend(q, k, v, cfg, mask)
    y = constrain(out, "act_attn_out") @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_forward(p: dict, x: jax.Array, kv_src: jax.Array, cfg: ModelConfig, kv_mask=None):
    """Encoder-decoder cross attention; no RoPE, no causality."""
    q, _, _ = _project_qkv(p, x, cfg)
    B, T, _ = kv_src.shape
    k = (kv_src @ p["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (kv_src @ p["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if kv_mask is None:
        mask = jnp.ones((B, 1, 1, x.shape[1], T), dtype=bool)
    else:
        mask = kv_mask[:, None, None, None, :]
    out = _attend(q, k, v, cfg, mask)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype) -> dict:
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_cache_prefill(cache_k, cache_v, k, v):
    """Write prefill k/v (B,S,K,hd) at offset 0 of per-layer cache (B,Smax,K,hd)."""
    ck = constrain(jax.lax.dynamic_update_slice_in_dim(cache_k, k, 0, axis=1), "decode_cache")
    cv = constrain(jax.lax.dynamic_update_slice_in_dim(cache_v, v, 0, axis=1), "decode_cache")
    return ck, cv


def attention_decode(
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cfg: ModelConfig,
    *,
    cache_k: jax.Array,  # (B, S_max, K, hd)
    cache_v: jax.Array,
    cache_len: jax.Array,  # int32 tokens already in cache: scalar, or (B,) per-slot
    window=None,
):
    """One decode step: append token's k/v, attend over valid prefix."""
    B, _, _ = x.shape
    S_max = cache_k.shape[1]
    q, k, v = _project_qkv(p, x, cfg)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim:
        pos = cache_len[:, None]
    else:
        pos = jnp.broadcast_to(cache_len[None, None], (B, 1))
    if cfg.rope_type == "mrope":
        rp = jnp.broadcast_to(pos[None], (3, B, 1))
    else:
        rp = pos
    q, k = _rope_qk(q, k, cfg, rp)
    q = constrain(q, "decode_q")
    if q.ndim == 4:
        # repeated layout: q is replicated at decode (1 token — negligible),
        # so regroup to (B,1,K,G,hd) and use the grouped einsum.  A
        # jnp.repeat of the cache would force the SPMD partitioner to
        # replicate the sequence-sharded cache (involuntary full remat).
        B_, S_, H_, hd_ = q.shape
        q = q.reshape(B_, S_, cfg.n_kv_heads, cfg.q_per_kv, hd_)
    if cache_len.ndim:  # scatter each slot's k/v at its own write offset
        rows = jnp.arange(B)
        cache_k = constrain(cache_k.at[rows, cache_len].set(k[:, 0]), "decode_cache")
        cache_v = constrain(cache_v.at[rows, cache_len].set(v[:, 0]), "decode_cache")
    else:
        cache_k = constrain(
            jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_len, axis=1), "decode_cache"
        )
        cache_v = constrain(
            jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_len, axis=1), "decode_cache"
        )
    kvpos = jnp.arange(S_max, dtype=jnp.int32)
    mask = causal_window_mask(pos, kvpos, window, kv_len=cache_len + 1)
    out = _attend(q, cache_k, cache_v, cfg, mask)
    y = out @ p["wo"]
    return y, cache_k, cache_v


def attention_decode_paged(
    p: dict,
    x: jax.Array,  # (B, T, d): T = 1 decode tick, T > 1 prefill chunk
    cfg: ModelConfig,
    *,
    cache_k: jax.Array,  # (num_blocks, block_size, K, hd) — shared block pool
    cache_v: jax.Array,
    block_table: jax.Array,  # (B, nb) int32 block ids in logical order
    cache_len: jax.Array,  # (B,) int32 tokens already in each row's blocks
    window=None,
    attn_mode: str = "gather",
):
    """Decode/chunk-prefill attention through a paged KV block table.

    The T new tokens' k/v are scattered into each row's own blocks at
    logical positions ``cache_len + t`` (page ``table[pos // bs]``, offset
    ``pos % bs``), then the row's blocks are gathered back into a
    ``(B, nb * bs)`` logical view and attended with the usual causal +
    ``kv_len`` masking — positions beyond a row's frontier (a block's
    previous owner, or the zero init) are masked exactly like stale arena
    rows in :func:`attention_decode`.  Rows that must stay inert (free /
    mid-prefill slots of the fixed decode batch) point their table at the
    reserved trash block 0 and carry ``cache_len = 0``.

    ``attn_mode="paged_pallas"`` replaces the gather + dense softmax with
    the fused Pallas kernel (:mod:`repro.kernels.paged_attention`): the
    scatter stays out here (the kernel must never write blocks the table
    does not reference), the gather disappears, and per-row HBM traffic
    scales with live blocks instead of the ``nb`` bucket.  The gather path
    stays as the reference / fallback; both paths agree to allclose (the
    online softmax is a different summation order, so not bitwise).
    """
    B, T, _ = x.shape
    nb, bs = block_table.shape[1], cache_k.shape[1]
    q, k, v = _project_qkv(p, x, cfg)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    pos = cache_len[:, None] + jnp.arange(T, dtype=jnp.int32)[None]  # (B, T)
    if cfg.rope_type == "mrope":
        rp = jnp.broadcast_to(pos[None], (3, B, T))
    else:
        rp = pos
    q, k = _rope_qk(q, k, cfg, rp)
    # Pin the to-be-scattered values: without the barrier XLA duplicates the
    # rope chain (one copy feeds the scatter, one the attention dot) and may
    # fuse the copies differently (FMA vs mul+add) — and differently again
    # between a scan body and the same step inlined.  The stored bits then
    # depend on which program wrote them, breaking the bit-exact equivalence
    # between the sequential verify scan and the T = k+1 parallel verify.
    q, k, v = jax.lax.optimization_barrier((q, k, v))
    q = constrain(q, "decode_q")
    if q.ndim == 4:
        # repeated layout: regroup to (B,T,K,G,hd) — see attention_decode
        B_, T_, H_, hd_ = q.shape
        q = q.reshape(B_, T_, cfg.n_kv_heads, cfg.q_per_kv, hd_)
    pages = jnp.take_along_axis(block_table, pos // bs, axis=1)  # (B, T)
    offs = pos % bs
    cache_k = constrain(cache_k.at[pages, offs].set(k), "decode_cache")
    cache_v = constrain(cache_v.at[pages, offs].set(v), "decode_cache")
    if attn_mode == "paged_pallas":
        from ..kernels.ops import paged_attention

        wnd = jnp.int32(2**30) if window is None else jnp.asarray(window, jnp.int32)
        out = paged_attention(
            q, cache_k, cache_v, block_table, cache_len, wnd,
            softcap=cfg.attn_softcap, scale=cfg.head_dim**-0.5,
        )
        y = out.reshape(B, T, cfg.attn_dim) @ p["wo"]
        return y, cache_k, cache_v
    if attn_mode != "gather":
        raise ValueError(f"unknown attn_mode {attn_mode!r}")
    kg = cache_k[block_table].reshape(B, nb * bs, *cache_k.shape[2:])
    vg = cache_v[block_table].reshape(B, nb * bs, *cache_v.shape[2:])
    kvpos = jnp.arange(nb * bs, dtype=jnp.int32)
    mask = causal_window_mask(pos, kvpos, window, kv_len=cache_len + T)
    out = _attend(q, kg, vg, cfg, mask)
    y = out @ p["wo"]
    return y, cache_k, cache_v
