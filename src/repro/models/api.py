"""Uniform model API over all families.

A ``Model`` bundles pure functions keyed off the config; params stay explicit
pytrees so pjit/shard_map wrap these functions directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .attention import init_cache as _init_kv_cache
from .common import ModelConfig


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- init ---------------------------------------------------------------
    def init(self, rng: jax.Array):
        if self.cfg.is_encoder_decoder:
            return encdec.init_encdec(rng, self.cfg)
        return transformer.init_lm(rng, self.cfg)

    # -- training -----------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_loss(params, batch, self.cfg)
        return transformer.lm_loss(params, batch, self.cfg)

    # -- full-sequence logits (evaluation, KLD/PPL metrics) ------------------
    def logits(self, params, batch, **kw) -> jax.Array:
        if self.cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frames"], self.cfg)
            out, _, _ = encdec.decode_full(params, batch["tokens"], enc_out, self.cfg, **kw)
            return out
        out, _, _, _ = transformer.forward(params, batch["tokens"], self.cfg, **kw)
        return out

    # -- forward with GLASS instrumentation ----------------------------------
    def logits_with_stats(self, params, batch):
        """Returns (logits, stats) — stats are per-layer A-signal sums."""
        if self.cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frames"], self.cfg)
            out, stats, _ = encdec.decode_full(
                params, batch["tokens"], enc_out, self.cfg, collect_stats=True
            )
            return out, stats
        out, _, stats, _ = transformer.forward(
            params, batch["tokens"], self.cfg, collect_stats=True
        )
        return out, stats

    def loss_with_probes(self, params, probes, batch):
        """CE loss with additive zero probes on every FFN hidden vector.
        grad w.r.t. ``probes`` gives the per-token dL/dh for I-GLASS."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frames"], cfg)
            logits, _, _ = encdec.decode_full(params, batch["tokens"], enc_out, cfg, probes=probes)
        else:
            logits, _, _, _ = transformer.forward(params, batch["tokens"], cfg, probes=probes)
        loss, _ = transformer.cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss

    def probe_zeros(self, batch_shape: Tuple[int, int]) -> jax.Array:
        """Zero probes (L, B, S, m) matching this config's FFN hidden width."""
        cfg = self.cfg
        B, S = batch_shape
        if cfg.family == "hybrid":
            raise NotImplementedError("hybrid probes: use shared-block stats instead")
        return jnp.zeros((cfg.n_layers, B, S, cfg.d_ff), jnp.float32)

    # -- serving ------------------------------------------------------------
    def prefill(self, params, inputs: Dict[str, jax.Array], max_len: int):
        """inputs: {"tokens": (B,S)} (+ "frames" for enc-dec).
        Returns (logits, cache, local_stats)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return encdec.encdec_prefill(params, inputs["frames"], inputs["tokens"], cfg, max_len)
        if cfg.family == "ssm":
            logits, _, stats, cache = transformer.rwkv_forward(
                params, inputs["tokens"], cfg, collect_stats=True, return_cache=True
            )
            return logits, cache, stats
        if cfg.family == "hybrid":
            return transformer.hybrid_prefill(params, inputs["tokens"], cfg, max_len)
        return transformer.dense_prefill(params, inputs["tokens"], cfg, max_len)

    def prefill_chunk(
        self,
        params,
        tokens: jax.Array,  # (B, T): the next T prompt tokens
        cache,  # paged leaves = whole block arenas; state leaves = this request's rows
        cache_len: jax.Array,  # (B,) int32 tokens already processed
        *,
        block_table=None,  # (B, nb) int32; None for pure-state families (ssm)
        attn_mode: str = "gather",  # "paged_pallas" = fused paged-attention kernel
    ):
        """Incremental prefill: extend the cache by T prompt tokens.

        Returns (logits (B,T,V), cache, chunk_stats); chunk_stats are sums
        over this chunk's tokens and merge across chunks by addition, so the
        finalized GLASS local signal is the same as single-shot prefill."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            raise NotImplementedError("chunked prefill targets decoder LMs")
        if cfg.family == "ssm":
            return transformer.rwkv_prefill_chunk(params, tokens, cfg, cache)
        if cfg.family == "hybrid":
            return transformer.hybrid_prefill_chunk(
                params, tokens, cfg, cache, block_table, cache_len,
                attn_mode=attn_mode,
            )
        return transformer.dense_prefill_chunk(
            params, tokens, cfg, cache, block_table, cache_len,
            attn_mode=attn_mode,
        )

    def decode_step(
        self,
        params,
        token: jax.Array,  # (B, 1); dense families accept (B, T) for the
        # parallel multi-token verify / forced replay
        cache,
        cache_len: jax.Array,  # scalar, or (B,) per-slot lengths (continuous batching)
        *,
        ffn_masks=None,  # shared (L, m), or per-slot with an extra B axis after L
        compact_layers=None,  # compact FFN pytree; per-slot adds a B axis after L
        block_table=None,  # (B, nb) int32: paged-KV serving (BlockPool)
        ffn_block_idx=None,  # active FFN block ids -> block-sparse pallas kernel
        ffn_block_size: int = 128,
        ffn_block_scale=None,  # per-(row, tile) f32 multipliers (per-request density)
        ffn_groups=None,  # static tuple: rows sharing a block list, batched
        # through the shared-list kernel (see dense_decode_step)
        ffn_row_perm=None,  # (B,) int32 row permutation matching ffn_groups
        attn_mode: str = "gather",  # "paged_pallas" = fused paged-attention kernel
    ):
        cfg = self.cfg
        if ffn_block_idx is not None and cfg.family not in ("dense", "vlm"):
            raise NotImplementedError("block-sparse decode targets dense-FFN families")
        if ffn_groups and ffn_block_idx is None:
            raise ValueError("ffn_groups requires ffn_block_idx (block-sparse decode)")
        if cfg.is_encoder_decoder:
            return encdec.encdec_decode_step(
                params, token, cache, cache_len, cfg, ffn_masks=ffn_masks, compact_layers=compact_layers
            )
        if cfg.family == "ssm":
            return transformer.rwkv_decode_step(
                params, token, cache, cache_len, cfg, ffn_masks=ffn_masks, compact_layers=compact_layers
            )
        if cfg.family == "hybrid":
            # mask layouts are rank-distinguished (never shape-sniffed):
            # (m,) shared | (1, m) MaskSet layout -> shared | (1, B, m)
            # per-slot arena -> (B, m)
            mask = ffn_masks
            if mask is not None and mask.ndim > 1:
                mask = mask[0]
            return transformer.hybrid_decode_step(
                params, token, cache, cache_len, cfg, shared_mask=mask,
                shared_compact=compact_layers, block_table=block_table,
                attn_mode=attn_mode,
            )
        return transformer.dense_decode_step(
            params, token, cache, cache_len, cfg, ffn_masks=ffn_masks,
            compact_layers=compact_layers, block_table=block_table,
            ffn_block_idx=ffn_block_idx, ffn_block_size=ffn_block_size,
            ffn_block_scale=ffn_block_scale,
            ffn_groups=ffn_groups, ffn_row_perm=ffn_row_perm,
            attn_mode=attn_mode,
        )

    def verify_steps(
        self,
        params,
        tokens: jax.Array,  # (B, T): the T candidate feeds, in order
        cache,
        cache_len,  # scalar, or (B,) per-slot lengths
        *,
        ffn_masks=None,
        compact_layers=None,
        block_table=None,
        ffn_block_idx=None,
        ffn_block_size: int = 128,
        ffn_block_scale=None,  # per-(row, tile) f32 multipliers (per-request density)
        seeds=None,  # (B,) int32: per-slot sampling seeds -> sampled verdicts
        pos0=None,  # (B,) int32 generated position of the FIRST verdict
        temperature=None,  # (B,) f32
        top_k=None,  # (B,) int32
        greedy_mask=None,  # (B,) bool: rows that verdict by argmax regardless
        parallel: bool = False,  # ONE T-token forward instead of the scan
        attn_mode: str = "gather",
    ):
        """Multi-token verification: feed ``tokens[:, j]`` sequentially
        through :meth:`decode_step` inside ONE jitted program (unrolled —
        see the loop comment below for why not ``lax.scan``), returning
        each position's verdict token and the advanced cache.

        This is the model-level primitive behind self-speculative decoding:
        feed ``[pending, d_1 .. d_k]`` under the TARGET tier's masks and
        read the verdict ``t_j`` at every position (accept the longest
        prefix with ``d_{j+1} == t_j``).  It runs the SAME single-token
        decode body the serving engines run, so KV rows, recurrent state,
        and logits are BIT-identical to ``T`` individual decode steps — the
        property the speculative state-invariant suite relies on for exact
        rollback.

        ``parallel=True`` is the one-forward path over all T positions that
        the sequential scan deferred: every feed is already known (they are
        all forced), so attention-backed families run ONE ``decode_step``
        with ``tokens (B, T)`` and the causal intra-chunk mask, and read a
        verdict per position.  The paged-pallas kernel keeps each query's
        op graph identical to a T = 1 tick (query axis on the kernel grid),
        so KV rows and verdicts stay BIT-identical to the scan — the
        state-invariant suite asserts it.  Recurrent families (ssm /
        hybrid) refuse: a chunkwise-parallel state update is a different
        reduction order than T sequential updates, which would break exact
        rollback.

        The verdict is the greedy argmax by default.  With ``seeds``/
        ``pos0``/``temperature``/``top_k`` given, it is the **counter-based
        positional sample** from the same pre-override logits
        (:func:`repro.serve.sampling.sample_positional` keyed on
        ``(seed, pos0 + j)``) — a pure function of (seed, position,
        logits), so a draft/verify pair under sampling is exactly as
        replayable as under greedy; ``greedy_mask`` rows keep the argmax
        verdict (mixed batches).

        Returns ``(verdicts (B, T) int32, cache)``.
        """
        kw = dict(
            ffn_masks=ffn_masks, compact_layers=compact_layers,
            block_table=block_table, ffn_block_idx=ffn_block_idx,
            ffn_block_size=ffn_block_size, ffn_block_scale=ffn_block_scale,
            attn_mode=attn_mode,
        )
        cache_len = jnp.asarray(cache_len, jnp.int32)
        sampled = seeds is not None
        if sampled:
            from ..serve.sampling import sample_positional

            pos0 = jnp.asarray(pos0, jnp.int32)
            if greedy_mask is None:
                greedy_mask = jnp.zeros(seeds.shape, bool)

        if parallel:
            if self.cfg.family not in ("dense", "moe", "vlm"):
                raise NotImplementedError(
                    "parallel verify targets attention-backed families; "
                    "recurrent state must advance token-by-token to stay "
                    "bit-identical to sequential decode"
                )
            logits, cache = self.decode_step(params, tokens, cache, cache_len, **kw)
            lg = logits.astype(jnp.float32)  # (B, T, V)
            g = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            if sampled:
                B, T = tokens.shape
                pos = pos0[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
                rep = lambda a: jnp.repeat(a, T, axis=0)
                s = sample_positional(
                    lg.reshape(B * T, -1), rep(seeds), pos.reshape(-1),
                    rep(temperature), rep(top_k),
                ).reshape(B, T)
                g = jnp.where(greedy_mask[:, None], g, s)
            return g, cache

        # UNROLLED python loop, not lax.scan: XLA fuses a while-loop body
        # differently than the same ops inlined, and the two disagree at
        # the last ulp deep in the layer stack — which would break the
        # bit-equality contract between this path and ``parallel=True``
        # (and between this path and T individual decode_step programs).
        # T = spec_k + 1 stays small, so the unroll cost is bounded.
        verdicts = []
        for j in range(tokens.shape[1]):
            logits, cache = self.decode_step(
                params, tokens[:, j:j + 1], cache, cache_len, **kw
            )
            cache_len = cache_len + 1
            lg = logits[:, -1].astype(jnp.float32)
            g = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            if sampled:
                s = sample_positional(lg, seeds, pos0 + j, temperature, top_k)
                g = jnp.where(greedy_mask, g, s)
            verdicts.append(g)
        return jnp.stack(verdicts, axis=1), cache

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = cfg.compute_dtype
        if cfg.is_encoder_decoder:
            raise NotImplementedError("enc-dec cache comes from prefill")
        if cfg.family == "ssm":
            from .rwkv6 import rwkv_heads

            H, P = rwkv_heads(cfg), cfg.rwkv_headdim
            return {
                "state": jnp.zeros((cfg.n_layers, batch, H, P, P), jnp.float32),
                "shift_tm": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
                "shift_cm": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
            }
        if cfg.family == "hybrid":
            return transformer.init_hybrid_cache(cfg, batch, max_len)
        return _init_kv_cache(cfg, batch, max_len, cfg.n_layers, dt)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
