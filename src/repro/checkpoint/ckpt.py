"""Checkpointing: atomic, rotated, async-capable, elastically resharding.

Layout per step:  <dir>/step_00001234/
    manifest.json   — tree structure, leaf shapes/dtypes, step, extra state
    <flat.leaf.path>.npy — one file per leaf (full logical array)

Leaves are stored as *full logical arrays* (gathered), so a checkpoint is
mesh-independent: restore onto any mesh by passing target shardings —
elastic scaling (fewer/more nodes after a failure) is a plain restore.
Writes go to a tmp dir + atomic rename; a crash mid-save never corrupts the
latest complete checkpoint.  ``AsyncCheckpointer`` moves serialization off
the training thread (device->host copy happens synchronously, disk I/O
async), the standard large-run pattern.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "###"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    keys = []

    def fill(path, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        keys.append(key)
        return flat[key]

    return jax.tree_util.tree_map_with_path(fill, template)


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree,
    extra: Optional[Dict] = None,
    keep: int = 3,
) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": int(step), "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{key}.npy", arr)
        manifest["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on the same filesystem
    _rotate(directory, keep)
    return final


def _rotate(directory: Path, keep: int):
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir())
    for old in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    steps = sorted(p.name for p in directory.glob("step_*") if (p / "manifest.json").exists())
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore_checkpoint(
    directory: str | Path,
    template,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[int, Any, Dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree (same structure) of NamedSharding — leaves
    are device_put with the *target* sharding, so the checkpoint can be
    loaded onto a different mesh than it was saved from (elastic restart)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_shard = _flatten(shardings) if shardings is not None else None

    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(d / f"{key}.npy")
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[key])
        flat[key] = arr
    tree = _unflatten_into(template, flat)
    return manifest["step"], tree, manifest.get("extra", {})


class AsyncCheckpointer:
    """Off-thread checkpoint writer with at-most-one outstanding save."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra, self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
