"""Pure-JAX AdamW with global-norm clipping and warmup-cosine schedule.

No optax in this environment — this is a minimal, production-shaped
implementation: moments stored in f32, params may be bf16 (updates computed
in f32, cast back), decoupled weight decay, bias correction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # f32 pytree like params
    nu: Any  # f32 pytree like params


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(oc.warmup_steps, 1))
    prog = jnp.clip(
        (step - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1.0 - oc.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def _decay_mask(path) -> bool:
    """Decay only >=2D weight matrices (skip norms, biases, scalars)."""
    return True  # refined per-leaf below using ndim


def adamw_update(
    params, grads, state: OptState, oc: OptConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads32, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = state.step + 1
    lr = schedule(oc, state.step)
    b1, b2 = oc.b1, oc.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if p.ndim >= 2:
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads32, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
