"""Training loop: data pipeline + AdamW + checkpoint/restart + watchdog.

Single-host reference implementation of the production control flow: the
same loop body runs under a multi-host launcher (per-host pipeline shard,
heartbeats, elastic restart from the latest checkpoint on failure).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from ..data.pipeline import PackedLM, PipelineState
from ..data.synthetic import SyntheticCorpus
from ..ft.watchdog import Heartbeat, StepWatchdog
from ..models.api import Model
from .optim import OptConfig, adamw_update, init_opt_state


@dataclass
class TrainConfig:
    steps: int = 200
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    host_index: int = 0
    host_count: int = 1
    opt: OptConfig = field(default_factory=OptConfig)


def train(
    model: Model,
    tc: TrainConfig,
    corpus: Optional[SyntheticCorpus] = None,
    rng: Optional[jax.Array] = None,
    log: Callable[[str], None] = print,
) -> Dict:
    """Returns {"params", "opt_state", "losses", "resumed_from"}."""
    corpus = corpus or SyntheticCorpus()
    rng = rng if rng is not None else jax.random.key(0)
    params = model.init(rng)
    opt_state = init_opt_state(params)
    pipe = PackedLM(corpus, tc.batch, tc.seq, tc.host_index, tc.host_count)
    start_step = 0
    resumed_from = None

    ckpt = AsyncCheckpointer(tc.ckpt_dir) if tc.ckpt_dir else None
    if ckpt and latest_step(tc.ckpt_dir) is not None:
        start_step, tree, extra = restore_checkpoint(
            tc.ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = tree["params"], tree["opt"]
        pipe.state = PipelineState.from_dict(extra["pipeline"])
        resumed_from = start_step
        log(f"[train] resumed from step {start_step}")

    oc = tc.opt

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw_update(params, grads, opt_state, oc)
        return params, opt_state, {**metrics, **om, "loss": loss}

    watchdog = StepWatchdog()
    hb = Heartbeat(tc.ckpt_dir, tc.host_index) if tc.ckpt_dir else None
    losses = []
    for step in range(start_step, tc.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.next_batch().items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        slow = watchdog.observe(step, dt)
        losses.append(loss)
        if hb:
            hb.beat(step)
        if slow:
            log(f"[train] straggler flagged at step {step}: {dt * 1e3:.0f} ms")
        if step % tc.log_every == 0:
            log(f"[train] step {step} loss {loss:.4f} ({dt * 1e3:.0f} ms)")
        if ckpt and (step + 1) % tc.ckpt_every == 0:
            ckpt.save(
                step + 1,
                {"params": params, "opt": opt_state},
                extra={"pipeline": pipe.state.to_dict()},
            )
    if ckpt:
        ckpt.save(
            tc.steps, {"params": params, "opt": opt_state},
            extra={"pipeline": pipe.state.to_dict()},
        )
        ckpt.wait()
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "resumed_from": resumed_from,
        "watchdog": watchdog,
    }
