"""Int8 error-feedback gradient compression for the DP all-reduce.

Per leaf: quantize (g + residual) to int8 with a shared per-leaf scale,
all-reduce the int8 payload over the data axis, dequantize, and carry the
quantization error into the next step (error feedback — keeps Adam
convergence, cf. 1-bit SGD / EF-SignSGD lineage).  Wire cost drops 4x vs
f32 (2x vs bf16); the scale sync is one scalar max-reduce per leaf.

``sync_grads`` runs *inside* an explicit-DP shard_map training step (see
``make_dp_train_step``) where per-shard local grads actually exist — under
plain pjit, XLA inserts its own all-reduce and there is nothing to compress.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .optim import OptConfig, adamw_update


def sync_leaf(g: jax.Array, r: jax.Array, axis: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce of one leaf (inside shard_map)."""
    x = g.astype(jnp.float32) + r
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    synced = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * scale
    synced = synced / n.astype(jnp.float32)
    new_r = x - q.astype(jnp.float32) * scale
    return synced, new_r


def sync_grads(grads: Any, residual: Any, axis: str = "data") -> Tuple[Any, Any]:
    out = jax.tree.map(lambda g, r: sync_leaf(g, r, axis), grads, residual)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and not isinstance(t[0], tuple)
    synced = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return synced, new_res


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_dp_train_step(
    loss_fn: Callable,  # loss_fn(params, batch) -> scalar
    oc: OptConfig,
    mesh: Mesh,
    axis: str = "data",
    compress: bool = True,
):
    """Explicit data-parallel train step under shard_map.

    Params/opt state replicated; batch sharded over ``axis``; grad sync is
    the int8 error-feedback all-reduce when ``compress`` (plain f32 psum
    otherwise, for the A/B convergence comparison in tests)."""

    def step(params, opt_state, residual, batch):
        def inner(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if compress:
                grads, residual = sync_grads(grads, residual, axis)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_params, new_opt, metrics = adamw_update(params, grads, opt_state, oc)
            loss = jax.lax.pmean(loss, axis)
            return new_params, new_opt, residual, {**metrics, "loss": loss}

        fn = shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P(), P(), P(axis)),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )
        return fn(params, opt_state, residual, batch)

    return jax.jit(step)
