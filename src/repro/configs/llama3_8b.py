"""Llama 3 8B [arXiv:2407.21783] — paper evaluation model (Tabs 2/3/5/6)."""
from ..models.common import ModelConfig
from .registry import register


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        ffn_act="silu",
        gated_ffn=True,
        rope_theta=500000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
        norm_eps=1e-5,
    )
