"""Qwen2-VL 72B [arXiv:2409.12191; hf]: M-RoPE; vision frontend is a STUB —
input_specs provide precomputed patch embeddings, positions are the text
stream (t=h=w) by default."""
from ..models.common import ModelConfig
from .registry import register


@register("qwen2-vl-72b")
def qwen2_vl_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        ffn_act="silu",
        gated_ffn=True,
        rope_type="mrope",
        mrope_sections=(16, 24, 24),  # half-dim units, sum = head_dim // 2
        rope_theta=1000000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
        norm_eps=1e-6,
    )
