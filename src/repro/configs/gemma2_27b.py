"""Gemma 2 27B [arXiv:2408.00118; hf]: local+global alternating attention,
logit/attn softcaps, GeGLU, sandwich norms, tied embeddings."""
from ..models.common import ModelConfig
from .registry import register


@register("gemma2-27b")
def gemma2_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        ffn_act="gelu",
        gated_ffn=True,
        rope_theta=10000.0,
        attn_softcap=50.0,
        logit_softcap=30.0,
        sliding_window=4096,
        attn_pattern="local_global",
        sandwich_norms=True,
        embed_scale=True,
        tie_embeddings=True,
        gqa_layout="grouped",  # kv=16 divides the model axis
        norm_eps=1e-6,
    )
