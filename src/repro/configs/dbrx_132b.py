"""DBRX 132B [hf:databricks/dbrx-base; unverified]: 16 experts top-4,
fine-grained MoE; experts divide the data axis -> clean DP+EP."""
from ..models.common import ModelConfig
from .registry import register


@register("dbrx-132b")
def dbrx_132b() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        ffn_act="silu",
        gated_ffn=True,
        n_experts=16,
        n_experts_per_tok=4,
        moe_strategy="dropping",
        rope_theta=500000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
    )
