"""RWKV6 (Finch) 7B [arXiv:2404.05892; hf]: attention-free, data-dependent
decay time-mix + squared-ReLU channel-mix (the GLASS target)."""
from ..models.common import ModelConfig
from .registry import register


@register("rwkv6-7b")
def rwkv6_7b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,  # = d_model / rwkv_headdim (bookkeeping only)
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        ffn_act="relu2",
        gated_ffn=False,
        rope_type="none",
        rwkv_headdim=64,
        rwkv_lora_rank=64,
        tie_embeddings=False,
        norm_eps=1e-5,
    )
