"""Yi 9B [arXiv:2403.04652; hf]: llama-arch, deep GQA (kv=4)."""
from ..models.common import ModelConfig
from .registry import register


@register("yi-9b")
def yi_9b() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        ffn_act="silu",
        gated_ffn=True,
        rope_theta=5000000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
        norm_eps=1e-5,
    )
