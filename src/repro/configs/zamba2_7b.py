"""Zamba2 7B [arXiv:2411.15242; unverified]: Mamba2 backbone with a shared
attention(+MLP) block applied every 6 layers. GLASS targets the shared
block's gated MLP (the only FFN in the architecture)."""
from ..models.common import ModelConfig
from .registry import register


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ffn_act="silu",
        gated_ffn=True,
        ssm_state=64,
        ssm_conv=4,
        mamba_headdim=64,
        mamba_expand=2,
        attn_every=6,
        tie_embeddings=True,
        gqa_layout="grouped",  # kv=32 divides the model axis
    )
