"""Whisper large-v3 [arXiv:2212.04356; unverified]: enc-dec, conv/mel
frontend STUBBED (input_specs provide frame embeddings).  Non-gated GELU FFN
(g_j = 1 branch of the paper's Eq. 3).  max_positions is extended beyond the
real model's 448 to satisfy the assigned 32k decode shape (DESIGN.md §6)."""
from ..models.common import ModelConfig
from .registry import register


@register("whisper-large-v3")
def whisper_large_v3() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        is_encoder_decoder=True,
        n_layers=32,
        n_enc_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        ffn_act="gelu",
        gated_ffn=False,
        rope_type="none",
        max_positions=40960,
        tie_embeddings=True,
        gqa_layout="grouped",  # 20 heads don't divide the model axis: attention replicates
        norm_eps=1e-5,
    )
