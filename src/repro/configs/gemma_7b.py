"""Gemma 7B [arXiv:2403.08295] — paper evaluation model (GeGLU, MHA)."""
from ..models.common import ModelConfig
from .registry import register


@register("gemma-7b")
def gemma_7b() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        ffn_act="gelu",
        gated_ffn=True,
        embed_scale=True,
        tie_embeddings=True,
        gqa_layout="grouped",
    )
