"""Mistral 7B [arXiv:2310.06825] — paper evaluation model."""
from ..models.common import ModelConfig
from .registry import register


@register("mistral-7b")
def mistral_7b() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        ffn_act="silu",
        gated_ffn=True,
        sliding_window=4096,
        rope_theta=10000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
        norm_eps=1e-5,
    )
