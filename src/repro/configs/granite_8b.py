"""Granite 8B (code) [arXiv:2405.04324; hf]: llama-arch."""
from ..models.common import ModelConfig
from .registry import register


@register("granite-8b")
def granite_8b() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49152,
        ffn_act="silu",
        gated_ffn=True,
        rope_theta=10000000.0,
        tie_embeddings=False,
        gqa_layout="repeated",
        norm_eps=1e-5,
    )
