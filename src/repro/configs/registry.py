"""Architecture registry: ``--arch <id>`` resolution + tiny test variants."""
from __future__ import annotations

from typing import Callable, Dict, List

from ..models.common import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def tiny_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small depth/width,
    few experts, tiny vocab — preserves every structural feature (GQA ratio,
    softcaps, alternation, MoE routing, SSM layout, enc-dec wiring)."""
    kw = dict(
        name=f"{cfg.name}-tiny",
        n_layers=4 if cfg.family != "hybrid" else 5,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, round(4 * cfg.n_kv_heads / max(cfg.n_heads, 1))),
        head_dim=16,
        d_ff=128,
        vocab_size=199,
        dtype="float32",
        remat="none",
        max_positions=4096,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, n_experts_per_tok=min(2, cfg.n_experts_per_tok), moe_chunk=16)
    if cfg.family == "hybrid":
        kw.update(ssm_state=16, mamba_headdim=16, attn_every=2)
    if cfg.family == "ssm":
        kw.update(rwkv_headdim=16, rwkv_lora_rank=8)
    if cfg.is_encoder_decoder:
        kw.update(n_enc_layers=2, n_layers=2)
    if cfg.rope_type == "mrope":
        kw.update(mrope_sections=(4, 2, 2))
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    return cfg.replace(**kw)
