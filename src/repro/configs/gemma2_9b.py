"""Gemma 2 9B [arXiv:2408.00118; hf]."""
from ..models.common import ModelConfig
from .registry import register


@register("gemma2-9b")
def gemma2_9b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        ffn_act="gelu",
        gated_ffn=True,
        attn_softcap=50.0,
        logit_softcap=30.0,
        sliding_window=4096,
        attn_pattern="local_global",
        sandwich_norms=True,
        embed_scale=True,
        tie_embeddings=True,
        gqa_layout="repeated",  # kv=8 < model axis; q heads 16 divide it
    )
