from . import (
    dbrx_132b,
    gemma2_27b,
    gemma2_9b,
    gemma_7b,
    granite_8b,
    grok1_314b,
    llama3_8b,
    mistral_7b,
    qwen2_vl_72b,
    rwkv6_7b,
    whisper_large_v3,
    yi_9b,
    zamba2_7b,
)
from .registry import get_config, list_archs, tiny_variant

ASSIGNED = [
    "gemma2-27b", "gemma2-9b", "yi-9b", "granite-8b", "qwen2-vl-72b",
    "grok-1-314b", "dbrx-132b", "zamba2-7b", "whisper-large-v3", "rwkv6-7b",
]
PAPER_MODELS = ["llama3-8b", "mistral-7b", "gemma-7b"]

__all__ = ["ASSIGNED", "PAPER_MODELS", "get_config", "list_archs", "tiny_variant"]
