"""Grok-1 314B [hf:xai-org/grok-1; unverified]: 8 experts top-2, softcaps.

Expert count (8) does not divide the 16-wide data axis, so the planner
falls back to FSDP sharding of expert d_model dims over data (see DESIGN.md;
expert-replication x2 is the hillclimb alternative)."""
from ..models.common import ModelConfig
from .registry import register


@register("grok-1-314b")
def grok1_314b() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        ffn_act="gelu",
        gated_ffn=True,
        n_experts=8,
        n_experts_per_tok=2,
        moe_strategy="dropping",
        attn_softcap=30.0,
        logit_softcap=30.0,
        embed_scale=True,
        tie_embeddings=False,
        gqa_layout="repeated",
    )
