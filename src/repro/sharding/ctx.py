"""Activation-sharding constraint context.

Model code stays sharding-agnostic: it calls ``constrain(x, "act_btd")`` at
block boundaries, which is a no-op unless a rule set is installed (by the
step builders / dry-run) via ``use_rules``.  Rules map logical activation
names to PartitionSpecs — the planner emits mode-specific rule sets, and the
perf loop swaps rule sets (e.g. Megatron-style sequence parallelism) without
touching model code.

Rule names:
  act_btd        residual stream (B, S, d)
  act_btf        FFN hidden (B, S, f)
  act_heads      attention/ssm head activations (B, S, heads..., hd)
  logits         LM head output (B, S, V)
  moe_expert     dispatched expert tensors (E, B, C, ...)
  decode_q       decode-time query (B, 1, heads..., hd)
  decode_cache   per-layer KV cache inside the decode scan (B, S_max, K, hd)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar = contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict[str, P]):
    token = _RULES.set((mesh, rules))
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Apply the named activation constraint if a rule set is active."""
    state = _RULES.get()
    if state is None:
        return x
    mesh, rules = state
    spec = rules.get(name)
    if spec is None:
        return x
    # pad/truncate the spec to the array rank (rules are written for the
    # canonical rank; scan bodies may see per-layer views without lead dims)
    entries = list(spec)
    if len(entries) > x.ndim:
        entries = entries[: x.ndim]
    entries += [None] * (x.ndim - len(entries))
    # drop axes that don't divide
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, ax in zip(x.shape, entries):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        fixed.append(ax if dim % n == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))
