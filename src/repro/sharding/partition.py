"""Sharding planner: maps parameter/cache/batch trees to PartitionSpecs.

Philosophy (t5x/MaxText-style, specialized per family):

  * ``model`` axis carries tensor parallelism: vocab, attention heads, FFN
    hidden width, per-expert FFN width, SSM/RWKV heads.
  * ``data`` axis carries batch (together with ``pod`` on multi-pod meshes);
    for MoE it doubles as the expert-parallel axis (classic DP+EP); in train
    mode it optionally FSDP-shards weight d_model dims and always ZeRO-shards
    optimizer moments.
  * divisibility is checked against the actual dim — anything that does not
    divide falls back to the next candidate (ultimately replication), so one
    planner serves every architecture in the pool.

Mode differences:
  * train/prefill — activations batch-sharded; attention sharded by heads.
  * decode — KV caches shard over heads when kv_heads % model == 0, else over
    *sequence* (flash-decode / split-K style: softmax over a seq-sharded axis
    lowers to all-reduce(max)/all-reduce(sum)); batch=1 long-context cells
    replicate batch and lean on sequence sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import ModelConfig


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclass
class Planner:
    cfg: ModelConfig
    mesh: Mesh
    mode: str = "train"  # train | prefill | decode
    fsdp: bool = False  # additionally shard weight d_model dims over data
    # pure_dp: no tensor parallelism — the model axis joins the batch axes and
    # weights are ZeRO-3/FSDP-sharded over (data, model).  The right regime
    # for small-dense training where TP all-reduces dominate (see §Perf).
    pure_dp: bool = False

    def __post_init__(self):
        self.model_n = 1 if self.pure_dp else self.mesh.shape.get("model", 1)
        self.data_n = self.mesh.shape.get("data", 1)
        pod = ("pod",) if "pod" in self.mesh.axis_names else ()
        if self.pure_dp:
            self.dp = pod + ("data", "model")
            self.fsdp_axes = ("data", "model")
            self.fsdp = True
        else:
            self.dp = pod + ("data",)
            self.fsdp_axes = ("data",)
        self.dp_n = int(np.prod([self.mesh.shape[a] for a in self.dp]))
        cfg = self.cfg
        self.kv_tp = (
            cfg.n_kv_heads % self.model_n == 0 and cfg.gqa_layout == "grouped"
        ) and not self.pure_dp
        self.q_tp = (
            self.kv_tp
            if cfg.gqa_layout == "grouped"
            else (cfg.n_heads % self.model_n == 0 and not self.pure_dp)
        )
        if self.pure_dp:
            self.kv_tp = self.q_tp = False

    # -- helpers -------------------------------------------------------------

    def _fits(self, dim: int, axes) -> bool:
        if axes is None:
            return True
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        n = int(np.prod([self.mesh.shape[a] for a in axes]))
        return dim % n == 0

    def _spec(self, shape, *tail) -> P:
        """Build spec: trailing ``tail`` entries align to trailing dims,
        leading (stacked-layer) dims replicate.  Drops axes that don't
        divide."""
        nd = len(shape)
        tail = list(tail)
        full = [None] * (nd - len(tail)) + tail
        out = []
        for dim, ax in zip(shape, full):
            out.append(ax if (ax is not None and self._fits(dim, ax)) else None)
        return P(*out)

    @staticmethod
    def _axes_used(spec) -> set:
        used = set()
        for e in spec:
            if e is None:
                continue
            used.update((e,) if isinstance(e, str) else e)
        return used

    def _maybe_fsdp(self, spec: P, shape) -> P:
        """In fsdp mode, shard the first replicated dim of a >=2D weight over
        the fsdp axes (weights only — callers skip 1D params)."""
        if not (self.fsdp and (self.mode == "train" or self.pure_dp)):
            return spec
        if "data" in self._axes_used(spec):
            return spec
        axes = self.fsdp_axes if len(self.fsdp_axes) > 1 else "data"
        n = int(np.prod([self.mesh.shape[a] for a in self.fsdp_axes]))
        out = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, ax) in enumerate(zip(shape, out)):
            if ax is None and dim % n == 0 and dim >= n:
                out[i] = axes
                break
        return P(*out)

    # -- parameter rules -----------------------------------------------------

    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        last = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""
        cfg = self.cfg

        if self.pure_dp:
            # no TP anywhere: >=2D weights are ZeRO-3/FSDP over (data, model),
            # 1D params replicate.
            if len(shape) >= 2:
                return self._maybe_fsdp(P(*([None] * len(shape))), shape)
            return P(*([None] * len(shape)))

        # embeddings / head
        if last == "embed":
            return self._spec(shape, "model", None)
        if last == "lm_head":
            return self._spec(shape, None, "model")
        if last == "pos_dec":
            return P(*([None] * len(shape)))

        # attention projections
        if parent in ("attn", "self_attn", "cross_attn"):
            if last == "wq":
                s = self._spec(shape, None, "model") if self.q_tp else self._spec(shape)
                return self._maybe_fsdp(s, shape)
            if last in ("wk", "wv"):
                s = self._spec(shape, None, "model") if self.kv_tp else self._spec(shape)
                return self._maybe_fsdp(s, shape)
            if last == "wo":
                s = self._spec(shape, "model", None) if self.q_tp else self._spec(shape)
                return self._maybe_fsdp(s, shape)

        # dense FFN
        if parent == "ffn" or (parent == "cm" and last in ("wk", "wv", "wr")):
            if last in ("w_up", "w_gate", "wk", "wr"):
                return self._maybe_fsdp(self._spec(shape, None, "model"), shape)
            if last in ("w_down", "wv"):
                return self._maybe_fsdp(self._spec(shape, "model", None), shape)

        # MoE: expert dim over data (EP) when divisible, else FSDP d_model
        if parent == "moe":
            if last == "router":
                return P(*([None] * len(shape)))
            E = cfg.n_experts * cfg.expert_replication  # replica slots
            ep = E % self.data_n == 0
            if last in ("w_up", "w_gate"):  # (..., E, d, f)
                if ep:
                    return self._spec(shape, "data", None, "model")
                return self._spec(shape, None, "data", "model")
            if last == "w_down":  # (..., E, f, d)
                if ep:
                    return self._spec(shape, "data", "model", None)
                return self._spec(shape, None, "model", "data")

        # mamba2 mixer
        if parent == "mixer":
            if last in ("w_z", "w_x", "w_dt"):
                return self._maybe_fsdp(self._spec(shape, None, "model"), shape)
            if last in ("w_B", "w_C", "conv_B_w", "conv_B_b", "conv_C_w", "conv_C_b"):
                return P(*([None] * len(shape)))
            if last == "conv_x_w":
                return self._spec(shape, "model", None)
            if last in ("conv_x_b", "norm_w"):
                return self._spec(shape, "model")
            if last in ("A_log", "D", "dt_bias"):
                return self._spec(shape, "model")
            if last == "out_proj":
                return self._maybe_fsdp(self._spec(shape, "model", None), shape)

        # rwkv6 time mix
        if parent == "tm":
            if last in ("wr", "wk", "wv", "wg"):
                return self._maybe_fsdp(self._spec(shape, None, "model"), shape)
            if last == "wo":
                return self._maybe_fsdp(self._spec(shape, "model", None), shape)
            if last == "u":
                return self._spec(shape, "model", None)
            return P(*([None] * len(shape)))

        # norms, biases, scalars, lora adapters: replicate
        return P(*([None] * len(shape)))

    def params(self, param_shapes) -> Any:
        """param_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

        def one(path, leaf):
            spec = self.param_spec(_path_str(path), leaf.shape)
            return NamedSharding(self.mesh, spec)

        return jax.tree_util.tree_map_with_path(one, param_shapes)

    def param_specs_tree(self, param_shapes) -> Any:
        def one(path, leaf):
            return self.param_spec(_path_str(path), leaf.shape)

        return jax.tree_util.tree_map_with_path(one, param_shapes)

    # -- optimizer state (ZeRO-1) --------------------------------------------

    def opt_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Moments: param spec + shard the first replicated dim over data
        (ZeRO-1) — unless the param spec already consumes the data axis
        (MoE expert-parallel / FSDP weights)."""
        spec = self.param_spec(path, shape)
        if "data" in self._axes_used(spec):
            return spec
        out = list(spec) + [None] * (len(shape) - len(spec))
        if len(shape) >= 2:
            for i, (dim, ax) in enumerate(zip(shape, out)):
                if ax is None and dim % self.data_n == 0 and dim >= self.data_n:
                    out[i] = "data"
                    break
        return P(*out)

    # -- batch / cache / activation rules ------------------------------------

    def batch_spec(self, batch: int) -> P:
        return P(self.dp if batch % self.dp_n == 0 else None, None)

    def data_shardings(self, batch_shapes: Dict[str, Any]) -> Dict[str, NamedSharding]:
        out = {}
        for name, sds in batch_shapes.items():
            b = sds.shape[0]
            b_ax = self.dp if b % self.dp_n == 0 else None
            spec = P(b_ax, *([None] * (len(sds.shape) - 1)))
            out[name] = NamedSharding(self.mesh, spec)
        return out

    def kv_cache_spec(self, shape: Tuple[int, ...]) -> P:
        """(L?, B, S_max, K, hd): heads over model when divisible, else
        sequence over model; batch over dp when divisible, else sequence
        additionally over data."""
        L_lead = len(shape) - 4
        B, S, K, hd = shape[-4:]
        b_ax = self.dp if B % self.dp_n == 0 else None
        if K % self.model_n == 0:
            k_ax, s_ax = "model", None
        else:
            k_ax, s_ax = None, "model"
        if b_ax is None and s_ax is None and S % self.data_n == 0:
            s_ax = "data"  # long-context batch=1: spread cache over data too
        elif b_ax is None and s_ax == "model" and S % (self.data_n * self.model_n) == 0:
            s_ax = ("data", "model")
        return P(*([None] * L_lead), b_ax, s_ax, k_ax, None)

    def cache_shardings(self, cache_shapes) -> Any:
        cfg = self.cfg

        def one(path, leaf):
            name = _path_str(path)
            last = name.split("/")[-1]
            shp = leaf.shape
            if last in ("k", "v", "xk", "xv"):
                return NamedSharding(self.mesh, self.kv_cache_spec(shp))
            if last in ("state",):  # rwkv (L,B,H,P,P)
                b_ax = self.dp if shp[1] % self.dp_n == 0 else None
                h_ax = "model" if shp[2] % self.model_n == 0 else None
                return NamedSharding(self.mesh, P(None, b_ax, h_ax, None, None))
            if last in ("ssm", "tail_ssm"):  # (..., B, H, N, P)
                lead = len(shp) - 4
                b_ax = self.dp if shp[-4] % self.dp_n == 0 else None
                h_ax = "model" if shp[-3] % self.model_n == 0 else None
                return NamedSharding(self.mesh, P(*([None] * lead), b_ax, h_ax, None, None))
            if last == "x" and "conv" in name:  # conv x-carry (..., B, K-1, d_in)
                lead = len(shp) - 3
                b_ax = self.dp if shp[-3] % self.dp_n == 0 else None
                c_ax = "model" if shp[-1] % self.model_n == 0 else None
                return NamedSharding(self.mesh, P(*([None] * lead), b_ax, None, c_ax))
            if last in ("B", "C") and "conv" in name:
                lead = len(shp) - 3
                b_ax = self.dp if shp[-3] % self.dp_n == 0 else None
                return NamedSharding(self.mesh, P(*([None] * lead), b_ax, None, None))
            if last in ("shift_tm", "shift_cm"):  # (L, B, d)
                b_ax = self.dp if shp[1] % self.dp_n == 0 else None
                return NamedSharding(self.mesh, P(None, b_ax, None))
            # fallback: replicate
            return NamedSharding(self.mesh, P(*([None] * len(shp))))

        return jax.tree_util.tree_map_with_path(one, cache_shapes)

    def logits_spec(self) -> P:
        b_ax = self.dp
        return P(b_ax, None, "model")

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- activation constraint rules (consumed via sharding.ctx.constrain) ----

    def activation_rules(self, batch: int, seq_parallel: bool = False) -> Dict[str, P]:
        """Mode- and cell-specific activation rule set.

        ``seq_parallel``: Megatron-style SP — residual stream sharded over the
        model axis along sequence between blocks (saves the layer-input stash
        16x in train; adds all-gather/reduce-scatter at block boundaries)."""
        cfg = self.cfg
        dp = self.dp
        b_ok = batch % self.dp_n == 0
        b_ax = dp if b_ok else None
        head_ax = "model" if self.q_tp else None
        n_slots = cfg.n_experts * cfg.expert_replication
        ep_ax = "data" if (n_slots and n_slots % self.data_n == 0) else None
        # K/V layout in attention compute:
        #   kv_tp            — heads sharded (grouped GQA, kv % model == 0)
        #   q sharded only   — kv replicated (repeated GQA; the per-shard q
        #                      slice picks its kv head locally)
        #   nothing sharded  — (whisper, 20 heads): shard the KV *sequence*
        #                      over model: scores/softmax partition over kv
        #                      (all-reduce row stats + psum of the value
        #                      contraction) — flash-decode at prefill scale.
        if self.kv_tp:
            kv_rule = P(b_ax, None, "model")
        elif head_ax:
            kv_rule = P(b_ax, None, None)
        else:
            kv_rule = P(b_ax, "model", None)
        rules = {
            "act_btd": P(b_ax, "model" if seq_parallel else None, None),
            "act_btf": P(b_ax, None, "model"),
            "act_heads": P(b_ax, None, head_ax),
            "act_kv": kv_rule,
            "act_attn_out": P(b_ax, None, head_ax),
            "act_state": P(b_ax, "model", None, None),  # (B, H, ...) ssm/rwkv state
            "logits": P(b_ax, None, "model"),
            "moe_expert": P(ep_ax, None, None, None),
            "moe_hidden": P(ep_ax, None, None, "model"),
        }
        # KV-cache layout (used by decode steps AND prefill cache emission)
        k_ax = "model" if cfg.n_kv_heads % self.model_n == 0 else None
        s_ax = None if k_ax else "model"
        if not b_ok and s_ax == "model":
            s_ax = ("data", "model")
        elif not b_ok and s_ax is None:
            s_ax = "data"
        rules["decode_cache"] = P(b_ax, s_ax, k_ax, None)
        if self.mode == "decode":
            rules["decode_q"] = P(b_ax, None, k_ax if cfg.gqa_layout == "grouped" else None)
        if self.pure_dp:
            # the model axis carries batch: strip it from every non-batch dim
            def strip(spec: P) -> P:
                out = [spec[0]] + [
                    None if (e == "model" or (isinstance(e, tuple) and "model" in e)) else e
                    for e in list(spec)[1:]
                ]
                return P(*out)

            rules = {k2: strip(v) for k2, v in rules.items()}
        return rules
