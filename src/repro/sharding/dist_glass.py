"""Distributed GLASS: shard-local compaction under shard_map.

Rank fusion runs on *replicated* score vectors — (L, m) f32 is tiny (a few
MB even for gemma2-27b), so exact global ranking costs one small all-gather.
Selection is shard-balanced (k/n per model shard) so the subsequent weight
gather never crosses a shard boundary; the gather itself runs under
shard_map with zero collectives.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..models.common import ModelConfig


def to_local_indices(idx: jax.Array, m: int, n_shards: int) -> jax.Array:
    """Global shard-balanced indices (..., k) -> local (..., n_shards, k/n).

    Requires indices grouped by shard (guaranteed by select_shard_balanced's
    sorted output)."""
    k = idx.shape[-1]
    per = m // n_shards
    loc = idx.reshape(idx.shape[:-1] + (n_shards, k // n_shards))
    offs = (jnp.arange(n_shards, dtype=idx.dtype) * per)[:, None]
    return loc - offs


def _gather_cols(w, i):  # w (..., d, m_local), i (k_local,)
    return jnp.take(w, i, axis=-1)


def _gather_rows(w, i):  # w (..., m_local, d), i (k_local,)
    return jnp.take(w, i, axis=-2)


def compact_ffn_sharded(
    mesh: Mesh,
    ffn_params: Dict[str, jax.Array],  # stacked (L, d, m) / (L, m, d), m sharded "model"
    idx_local: jax.Array,  # (L, n_shards, k/n), dim1 sharded "model"
) -> Dict[str, jax.Array]:
    """Per-shard gather of selected FFN units; no collectives."""

    def kernel(w_up, w_down, w_gate, il):
        il = il[:, 0]  # (L, 1, k/n) -> (L, k/n)
        out = {
            "w_up": jax.vmap(_gather_cols)(w_up, il),
            "w_down": jax.vmap(_gather_rows)(w_down, il),
        }
        if w_gate is not None:
            out["w_gate"] = jax.vmap(_gather_cols)(w_gate, il)
        return out

    has_gate = "w_gate" in ffn_params
    in_specs = (
        P(None, None, "model"),
        P(None, "model", None),
        P(None, None, "model") if has_gate else None,
        P(None, "model", None),
    )
    out_specs = {"w_up": P(None, None, "model"), "w_down": P(None, "model", None)}
    if has_gate:
        out_specs["w_gate"] = P(None, None, "model")
    fn = shard_map(
        kernel, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return fn(
        ffn_params["w_up"],
        ffn_params["w_down"],
        ffn_params.get("w_gate"),
        idx_local,
    )


def compact_moe_sharded(mesh: Mesh, moe_params, idx_local):
    """MoE per-expert compaction. weights (L, E, d, f) / (L, E, f, d) with f
    sharded over model; idx_local (L, E, n, k/n)."""

    def kernel(w_up, w_down, w_gate, router, il):
        il = il[:, :, 0]  # (L, E, k/n)
        g2 = jax.vmap(jax.vmap(_gather_cols))
        g2r = jax.vmap(jax.vmap(_gather_rows))
        out = {
            "router": router,
            "w_up": g2(w_up, il),
            "w_down": g2r(w_down, il),
        }
        if w_gate is not None:
            out["w_gate"] = g2(w_gate, il)
        return out

    has_gate = "w_gate" in moe_params
    ep = P(None, None, None, "model")  # (L,E,d,f)
    dn = P(None, None, "model", None)  # (L,E,f,d)
    in_specs = (ep, dn, ep if has_gate else None, P(None, None, None), P(None, None, "model", None))
    out_specs = {"router": P(None, None, None), "w_up": ep, "w_down": dn}
    if has_gate:
        out_specs["w_gate"] = ep
    fn = shard_map(kernel, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    return fn(
        moe_params["w_up"],
        moe_params["w_down"],
        moe_params.get("w_gate"),
        moe_params["router"],
        idx_local,
    )


def compact_rwkv_cm_sharded(mesh: Mesh, cm_params, idx_local):
    """RWKV channel-mix: wk (L,d,f), wv (L,f,d); wr/mu pass through."""

    def kernel(wk, wv, il):
        il = il[:, 0]
        return {
            "wk": jax.vmap(_gather_cols)(wk, il),
            "wv": jax.vmap(_gather_rows)(wv, il),
        }

    fn = shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(None, None, "model"), P(None, "model", None), P(None, "model", None)),
        out_specs={"wk": P(None, None, "model"), "wv": P(None, "model", None)},
        check_vma=False,
    )
    out = fn(cm_params["wk"], cm_params["wv"], idx_local)
    return {"mu": cm_params["mu"], "wr": cm_params["wr"], **out}
