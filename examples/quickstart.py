"""GLASS quickstart: train a tiny LM, compute the NPS global prior, build a
fused mask from a short prompt, and decode with the compact FFN.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import GlassConfig, NPSConfig, build_masks, compact_params, compute_global_prior
from repro.data.synthetic import SyntheticCorpus
from repro.data.tokenizer import BOS_ID, decode, encode
from repro.models import ModelConfig, build_model
from repro.train.loop import TrainConfig, train

cfg = ModelConfig(
    name="quickstart", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=384, vocab_size=300, dtype="float32", remat="none",
)
model = build_model(cfg)

print("== 1. train a tiny LM on the synthetic corpus ==")
out = train(model, TrainConfig(steps=200, batch=16, seq=128, log_every=50), SyntheticCorpus())
params = out["params"]

print("== 2. offline: NPS global prior (A-GLASS variant) ==")
npc = NPSConfig(n_seqs=32, seq_len=64, batch=16, bos_id=BOS_ID)
prior = compute_global_prior(model, params, jax.random.key(1), npc, variant="A")
print("prior shape:", prior.shape)

print("== 3. per request: prefill a SHORT prompt, fuse, compact ==")
prompt_text = SyntheticCorpus().document(10_000)[:24]
prompt = jnp.asarray(encode(prompt_text))[None]
S = prompt.shape[1]
logits, cache, local_stats = model.prefill(params, {"tokens": prompt}, S + 32)
masks = build_masks(local_stats, prior, GlassConfig(density=0.5, lam=0.5))
compact = compact_params(model, params, masks.idx)
print(f"kept {int(masks.mask.sum())} of {masks.mask.size} FFN units "
      f"(density {float(masks.mask.mean()):.2f})")

print("== 4. steady-state decode with the compact FFN (50% FLOPs/bytes) ==")
tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
gen = [int(tok[0, 0])]
for i in range(31):
    lg, cache = model.decode_step(params, tok, cache, jnp.int32(S + i), compact_layers=compact)
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    gen.append(int(tok[0, 0]))
print("prompt:      ", prompt_text)
print("continuation:", decode(gen))
