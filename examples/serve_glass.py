"""Streaming serving demo: the per-request generation API.

Staggered requests flow through ``PagedEngine.add_request`` with their own
``SamplingParams`` (greedy or seeded counter-based sampling) and
``GlassParams`` (per-request density / speculative draft length); tokens
are printed AS THEY ARRIVE from ``engine.step()``'s RequestOutput deltas,
one request finishes early on a stop token, and one is aborted mid-flight.
The dense-agreement and paper-fidelity metrics follow.

    PYTHONPATH=src:. python examples/serve_glass.py
"""
import numpy as np

from benchmarks.common import TINY_LLAMA, build_bundle, sparse_eval_logits
from benchmarks.metrics import dense_trajectory_ppl, top100_kld
from repro.core import GlassConfig, GlassParams
from repro.serve.engine import PagedEngine
from repro.serve.sampling import SamplingParams

b = build_bundle(TINY_LLAMA, n_samples=8)
model, params = b.model, b.params

print("== streaming frontend: mixed per-request policies, 3 slots ==")
rng = np.random.RandomState(0)
eng = PagedEngine(
    model, params, max_slots=3, max_len=48, block_size=8, chunk_tokens=8,
    glass=GlassConfig(density=0.5, draft_ratio=0.5),
    global_prior=b.priors["I_nps"],
)

# one policy per request: greedy, seeded-sampled, half-density, speculative
policies = [
    ("greedy, eos=35  ", SamplingParams.make_greedy(eos_token_id=35), None),
    ("sampled seed=7  ", SamplingParams(temperature=0.9, top_k=40, seed=7), None),
    ("density 0.25    ", None, GlassParams(density=0.25, spec_k=0)),
    ("speculative k=2 ", None, GlassParams(spec_k=2)),
    ("sampled seed=11 ", SamplingParams(temperature=1.1, seed=11), None),
    ("greedy          ", None, None),
]
uids = {}
for i, seq in enumerate(b.sequences[: len(policies)]):
    name, sp, gp = policies[i]
    uid = eng.add_request(
        np.asarray(seq[0, :8], np.int32), int(rng.randint(8, 24)),
        sampling=sp, glass=gp, arrival=3 * i // 2,
    )
    uids[uid] = name

aborted = False
while eng._work_remaining():
    for out in eng.step():
        if out.finished:
            print(f"req {out.uid} [{uids[out.uid]}] FINISHED "
                  f"({out.finish_reason}) t={out.finished_step:3d}  "
                  f"{out.tokens.shape[0]:2d} tokens")
        elif len(out.new_tokens):
            # tokens stream in as they are accepted (speculative rounds can
            # deliver several per tick)
            print(f"req {out.uid} [{uids[out.uid]}] t={eng.t:3d}  "
                  f"+{[int(x) for x in out.new_tokens]}")
    if not aborted and eng.t >= 8 and 5 in eng.lc.entries:
        out = eng.abort(5)
        if out is not None:
            print(f"req 5 [{uids[5]}] ABORTED  ({out.tokens.shape[0]} tokens kept)")
        aborted = True

print(f"engine drained in {eng.t} steps; "
      f"speculative rounds: {eng.spec_ticks}, "
      f"draft acceptance: {eng.spec_telemetry['draft_acceptance_rate']:.2f}")

print("== fidelity vs dense trajectory (paper metrics) ==")
for name, lam in [("GRIFFIN (local-only)", 0.0), ("GLASS (fused)", 0.5)]:
    ppls, klds = [], []
    for seq, dl in zip(b.sequences, b.dense_logits):
        sl = sparse_eval_logits(model, params, seq, b.prompt_len,
                                b.priors["I_nps"], GlassConfig(density=0.5, lam=lam))
        ppls.append(dense_trajectory_ppl(sl, seq[0], b.prompt_len))
        klds.append(top100_kld(dl, sl, b.prompt_len))
    print(f"{name:24s} PPL {np.mean(ppls):7.4f}   top-100 KLD {np.mean(klds):7.4f}")
