"""End-to-end serving driver: batched requests through the Engine, dense vs
GRIFFIN (local-only) vs GLASS, reporting dense-trajectory fidelity.

    PYTHONPATH=src python examples/serve_glass.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TINY_LLAMA, build_bundle, sparse_eval_logits
from benchmarks.metrics import dense_trajectory_ppl, top100_kld
from repro.core import GlassConfig
from repro.serve.engine import Engine

b = build_bundle(TINY_LLAMA, n_samples=8)
model, params = b.model, b.params

print("== batched serving: 8 requests, dense vs GLASS engine ==")
prompts = jnp.concatenate([s[:, :8] for s in b.sequences[:4]], axis=0)
eng_dense = Engine(model, params)
eng_glass = Engine(model, params, glass=GlassConfig(density=0.5),
                   global_prior=b.priors["I_nps"])
res_d = eng_dense.generate(prompts, max_new=16)
res_g = eng_glass.generate(prompts, max_new=16)
agree = float(np.mean(res_d.tokens == res_g.tokens))
print(f"greedy token agreement dense vs GLASS@50%: {agree:.2%}")

print("== fidelity vs dense trajectory (paper metrics) ==")
for name, lam in [("GRIFFIN (local-only)", 0.0), ("GLASS (fused)", 0.5)]:
    ppls, klds = [], []
    for seq, dl in zip(b.sequences, b.dense_logits):
        sl = sparse_eval_logits(model, params, seq, b.prompt_len,
                                b.priors["I_nps"], GlassConfig(density=0.5, lam=lam))
        ppls.append(dense_trajectory_ppl(sl, seq[0], b.prompt_len))
        klds.append(top100_kld(dl, sl, b.prompt_len))
    print(f"{name:24s} PPL {np.mean(ppls):7.4f}   top-100 KLD {np.mean(klds):7.4f}")
