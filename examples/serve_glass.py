"""Queue-driven serving demo: staggered requests through the
continuous-batching engine, per-request GLASS masks, dense-agreement and
paper fidelity metrics.

    PYTHONPATH=src:. python examples/serve_glass.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TINY_LLAMA, build_bundle, sparse_eval_logits
from benchmarks.metrics import dense_trajectory_ppl, top100_kld
from repro.core import GlassConfig
from repro.serve.engine import ContinuousEngine
from repro.serve.scheduler import Request

b = build_bundle(TINY_LLAMA, n_samples=8)
model, params = b.model, b.params

print("== continuous batching: 8 staggered requests, 3 slots ==")
rng = np.random.RandomState(0)
requests = [
    Request(
        uid=i,
        prompt=np.asarray(seq[0, :8], np.int32),
        max_new=int(rng.randint(8, 24)),
        arrival=int(3 * i // 2),  # requests trickle in while others decode
    )
    for i, seq in enumerate(b.sequences)
]

eng_dense = ContinuousEngine(model, params, max_slots=3, max_len=48)
eng_glass = ContinuousEngine(
    model, params, max_slots=3, max_len=48,
    glass=GlassConfig(density=0.5), global_prior=b.priors["I_nps"],
)
done_d = eng_dense.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in requests])
done_g = eng_glass.run(requests)

agree_total = 0
tok_total = 0
for r in requests:
    d, g = done_d[r.uid], done_g[r.uid]
    agree = int(np.sum(d.tokens == g.tokens))
    agree_total += agree
    tok_total += r.max_new
    print(
        f"req {r.uid}: arrived t={r.arrival:2d} admitted t={g.admitted_step:2d} "
        f"finished t={g.finished_step:2d}  {r.max_new:2d} tokens  "
        f"dense-agreement {agree}/{r.max_new}"
    )
print(f"engine drained in {eng_glass.t} steps; "
      f"greedy token agreement dense vs GLASS@50%: {agree_total / tok_total:.2%}")

print("== fidelity vs dense trajectory (paper metrics) ==")
for name, lam in [("GRIFFIN (local-only)", 0.0), ("GLASS (fused)", 0.5)]:
    ppls, klds = [], []
    for seq, dl in zip(b.sequences, b.dense_logits):
        sl = sparse_eval_logits(model, params, seq, b.prompt_len,
                                b.priors["I_nps"], GlassConfig(density=0.5, lam=lam))
        ppls.append(dense_trajectory_ppl(sl, seq[0], b.prompt_len))
        klds.append(top100_kld(dl, sl, b.prompt_len))
    print(f"{name:24s} PPL {np.mean(ppls):7.4f}   top-100 KLD {np.mean(klds):7.4f}")
