"""Offline NPS prior computation (paper Sec. 3.3): generate from the model
under the null prompt, accumulate A^g and I^g, inspect their agreement.

    PYTHONPATH=src python examples/nps_prior.py
"""
import jax
import numpy as np

from benchmarks.common import TINY_LLAMA, trained_model
from repro.core import NPSConfig, compute_global_prior
from repro.core.nps import nps_corpus
from repro.data.tokenizer import BOS_ID, decode

model, params = trained_model(TINY_LLAMA)
npc = NPSConfig(n_seqs=16, seq_len=64, batch=16, bos_id=BOS_ID)

print("== sample NPS generations (null prompt, hot-temperature start) ==")
corpus = nps_corpus(model, params, jax.random.key(3), npc)
for row in np.asarray(corpus[:3]):
    print("  ", decode(row)[:72])

print("== A^g vs I^g priors ==")
pa = compute_global_prior(model, params, jax.random.key(3), npc, "A")
pi = compute_global_prior(model, params, jax.random.key(3), npc, "I")
for l in range(pa.shape[0]):
    ra = np.argsort(np.argsort(-np.asarray(pa[l])))
    ri = np.argsort(np.argsort(-np.asarray(pi[l])))
    rho = np.corrcoef(ra, ri)[0, 1]
    top_overlap = len(set(np.argsort(-pa[l])[:64]) & set(np.argsort(-pi[l])[:64])) / 64
    print(f"  layer {l}: spearman(A,I)={rho:+.3f}  top-50% overlap={top_overlap:.2f}")
