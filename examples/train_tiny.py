"""Training driver with checkpoint/restart and straggler watchdog: trains a
~5M-param model a few hundred steps, simulates a failure, resumes.

    PYTHONPATH=src python examples/train_tiny.py
"""
import tempfile

from repro.data.synthetic import SyntheticCorpus
from repro.models import ModelConfig, build_model
from repro.train.loop import TrainConfig, train

cfg = ModelConfig(
    name="train-demo", family="dense", n_layers=6, d_model=256, n_heads=8,
    n_kv_heads=4, head_dim=32, d_ff=768, vocab_size=300, dtype="float32", remat="none",
)
model = build_model(cfg)
corpus = SyntheticCorpus()

with tempfile.TemporaryDirectory() as d:
    print("== phase 1: train to step 60, checkpoint every 30 ==")
    train(model, TrainConfig(steps=60, batch=8, seq=128, ckpt_dir=d, ckpt_every=30, log_every=20), corpus)
    print("== simulated failure; relaunch resumes from the checkpoint ==")
    out = train(model, TrainConfig(steps=120, batch=8, seq=128, ckpt_dir=d, ckpt_every=30, log_every=20), corpus)
    print(f"resumed from step {out['resumed_from']}; "
          f"final loss {out['losses'][-1]:.4f}; "
          f"stragglers flagged: {len(out['watchdog'].slow_steps)}")
