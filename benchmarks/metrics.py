"""Paper evaluation metrics (App. B.2): dense-trajectory PPL and top-100 KLD.

Both are deviation-from-dense metrics: the dense model's own generation is
the reference trajectory; PPL measures how unlikely that trajectory is under
the sparsified model, KLD compares next-token distributions restricted to
the 100 most probable tokens under the dense model (renormalized).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_trajectory_ppl(
    sparse_logits: jax.Array,  # (S, V) teacher-forced over [prompt + dense gen]
    tokens: jax.Array,  # (S,) the full sequence (labels are tokens shifted)
    gen_start: int,  # first generated position (loss only over generation)
) -> float:
    lp = jax.nn.log_softmax(sparse_logits.astype(jnp.float32), axis=-1)
    # logits[t] predicts tokens[t+1]
    nll = -jnp.take_along_axis(lp[:-1], tokens[1:, None], axis=-1)[:, 0]
    region = nll[gen_start - 1 :]
    return float(jnp.exp(jnp.mean(region)))


def top100_kld(
    dense_logits: jax.Array,  # (S, V)
    sparse_logits: jax.Array,  # (S, V)
    gen_start: int,
    k: int = 100,
) -> float:
    d = dense_logits.astype(jnp.float32)[gen_start - 1 : -1]
    s = sparse_logits.astype(jnp.float32)[gen_start - 1 : -1]
    k = min(k, d.shape[-1])
    vals, idx = jax.lax.top_k(d, k)
    dp = jax.nn.softmax(vals, axis=-1)
    sp_sel = jnp.take_along_axis(s, idx, axis=-1)
    # renormalize the sparse distribution over the same support
    sp = jax.nn.softmax(sp_sel, axis=-1)
    kl = jnp.sum(dp * (jnp.log(dp + 1e-20) - jnp.log(sp + 1e-20)), axis=-1)
    return float(jnp.mean(kl))


def token_accuracy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> float:
    pred = jnp.argmax(logits, axis=-1)
    ok = (pred == labels).astype(jnp.float32)
    if mask is not None:
        return float(jnp.sum(ok * mask) / jnp.maximum(jnp.sum(mask), 1.0))
    return float(jnp.mean(ok))
