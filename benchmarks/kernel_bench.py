"""Fused paged-attention kernel benchmark (``BENCH_kernels.json``).

Two effects, reported separately because they live on different machines:

  * **Gathered bytes per token** (analytic, hardware-independent): the
    gather path materializes every row's full ``(nb * bs)`` logical KV
    view per decode tick — HBM traffic scales with the *allocated* pow2
    bucket.  The fused kernel streams only the live blocks
    (``ceil((cache_len + T) / bs)``), skipping dead and out-of-window
    table entries at the grid level — traffic scales with the *occupied*
    cache.  The sweep walks (B, nb, bs, cache_len) and reports both,
    plus the ratio; CI asserts the ratio tracks occupancy, not capacity.
  * **Wall-clock** (measured): per-call latency of the gather attention
    vs the fused kernel, and a sequential vs parallel speculative-verify
    engine comparison at spec_k in {2, 4}.  CAVEAT: on CPU the kernel
    runs through the Pallas *interpreter* — its absolute wall-clock is
    an emulation artifact and routinely LOSES to the native XLA gather;
    the numbers are recorded to catch regressions in the interpreter
    path, not as an acceleration claim.  The bytes-per-token table and
    the accelerator guides carry the perf story; re-run on a TPU host
    (``interpret=False`` compiles the real kernel) for true latency.

Run: ``PYTHONPATH=src python benchmarks/kernel_bench.py``
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GlassConfig
from repro.kernels.ops import paged_attention
from repro.models import ModelConfig, build_model
from repro.serve.engine import PagedEngine

OUT = Path(__file__).parent / "BENCH_kernels.json"

CFG = ModelConfig(
    name="kb-dense", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=101, dtype="float32",
    remat="none",
)

F32 = 4  # bytes


def _bytes_per_token(nb, bs, cache_len, T, K, hd):
    """Per layer, per row: k + v bytes the attention path must read."""
    gather = nb * bs * K * hd * 2 * F32
    live_blocks = -(-(cache_len + T) // bs)
    fused = live_blocks * bs * K * hd * 2 * F32
    return gather, fused


def bytes_sweep():
    K, hd = CFG.n_kv_heads, CFG.head_dim
    rows = []
    for B, nb, bs in [(4, 8, 16), (4, 16, 16), (8, 32, 16), (8, 64, 32)]:
        for frac in (0.25, 0.5, 1.0):
            cache_len = max(1, int(nb * bs * frac) - 1)
            g, f = _bytes_per_token(nb, bs, cache_len, 1, K, hd)
            rows.append({
                "B": B, "nb": nb, "bs": bs, "cache_len": cache_len,
                "occupancy": frac,
                "gather_bytes_per_token": g,
                "fused_bytes_per_token": f,
                "fused_over_gather": round(f / g, 4),
            })
    return rows


def _timeit(fn, reps=20):
    jax.block_until_ready(fn())  # warm: compile outside the timed region
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def wallclock_sweep():
    """Gather attention vs fused kernel, one (B, nb, bs) point per row."""
    rng = np.random.RandomState(0)
    K, hd, G = 2, 16, 2
    rows = []
    for B, nb, bs, cache_len in [(4, 8, 16, 100), (8, 16, 16, 200)]:
        N = nb * B + 1
        cache_k = jnp.asarray(rng.randn(N, bs, K, hd), jnp.float32)
        cache_v = jnp.asarray(rng.randn(N, bs, K, hd), jnp.float32)
        tab = np.zeros((B, nb), np.int32)
        need = -(-(cache_len + 1) // bs)
        nxt = 1
        for b in range(B):
            for j in range(need):
                tab[b, j] = nxt
                nxt += 1
        btab = jnp.asarray(tab)
        clen = jnp.full((B,), cache_len, jnp.int32)
        q = jnp.asarray(rng.randn(B, 1, K, G, hd), jnp.float32)

        @jax.jit
        def gather_attn(q, ck, cv, tab, cl):
            kg = ck[tab].reshape(B, nb * bs, K, hd)
            vg = cv[tab].reshape(B, nb * bs, K, hd)
            qpos = cl[:, None]
            kpos = jnp.arange(nb * bs)
            mask = qpos[:, :, None] >= kpos
            s = jnp.einsum("btkgd,bnkd->btkgn", q, kg) * hd**-0.5
            s = jnp.where(mask[:, :, None, None, :], s, -2.0e38)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("btkgn,bnkd->btkgd", p, vg)

        t_gather = _timeit(lambda: gather_attn(q, cache_k, cache_v, btab, clen))
        t_fused = _timeit(
            lambda: paged_attention(q, cache_k, cache_v, btab, clen,
                                    jnp.int32(2**30))
        )
        rows.append({
            "B": B, "nb": nb, "bs": bs, "cache_len": cache_len,
            "gather_ms": round(t_gather * 1e3, 3),
            "fused_interpret_ms": round(t_fused * 1e3, 3),
        })
    return rows


def verify_sweep():
    """Sequential vs parallel speculative verify, spec_k in {2, 4}."""
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    prior = jnp.abs(jax.random.normal(jax.random.key(7),
                                      (CFG.n_layers, CFG.d_ff)))
    out = {}
    for spec_k in (2, 4):
        results = {}
        tokens = {}
        for mode in ("sequential", "parallel"):
            eng = PagedEngine(
                model, params, max_slots=4, max_len=96, block_size=16,
                chunk_tokens=8, spec_k=spec_k, attn_mode="paged_pallas",
                verify_mode=mode,
                glass=GlassConfig(density=0.5, draft_ratio=0.5),
                global_prior=prior, glass_mode="compact",
            )
            rng = np.random.RandomState(3)
            reqs = [(rng.randint(3, 101, size=8).astype(np.int32), 24)
                    for _ in range(4)]
            # warm the jit caches with a first pass, then time a second
            for rep in range(2):
                for i, (p, n) in enumerate(reqs):
                    eng.add_request(p.copy(), n, uid=rep * 10 + i)
                t0 = time.perf_counter()
                outs = {}
                for _ in range(600):
                    for o in eng.step():
                        if o.finished:
                            outs[o.uid] = list(map(int, o.tokens))
                    if not eng.lc.entries:
                        break
                dt = time.perf_counter() - t0
            results[mode] = {
                "wall_s": round(dt, 3),
                "spec_ticks": eng.spec_ticks,
                "acceptance": round(
                    eng.spec_accepted / max(1, eng.spec_drafted), 4),
            }
            tokens[mode] = outs
        identical = tokens["sequential"] == tokens["parallel"]
        out[f"spec_k={spec_k}"] = {
            **results, "streams_identical": bool(identical),
        }
        assert identical, f"verify streams diverged at spec_k={spec_k}"
    return out


def main():
    bytes_rows = bytes_sweep()
    report = {
        "config": {
            "model": CFG.name, "n_kv_heads": CFG.n_kv_heads,
            "head_dim": CFG.head_dim, "dtype": "float32",
            "backend": jax.default_backend(),
        },
        "bytes_per_token": {
            "note": "analytic k+v bytes per decode token per layer per row; "
                    "gather reads the allocated nb*bs bucket, fused reads "
                    "ceil((cache_len+T)/bs) live blocks",
            "sweep": bytes_rows,
        },
        "wall_clock": {
            "caveat": "CPU runs the kernel through the Pallas interpreter — "
                      "absolute latency is an emulation artifact; re-run on "
                      "an accelerator host for real numbers",
            "sweep": wallclock_sweep(),
        },
        "speculative_verify": verify_sweep(),
    }
    # headline: fused traffic tracks occupancy, not allocation
    full = [r for r in bytes_rows if r["occupancy"] == 1.0]
    quarter = [r for r in bytes_rows if r["occupancy"] == 0.25]
    report["headline"] = {
        "fused_over_gather_at_quarter_occupancy": round(
            float(np.mean([r["fused_over_gather"] for r in quarter])), 4),
        "fused_over_gather_at_full_occupancy": round(
            float(np.mean([r["fused_over_gather"] for r in full])), 4),
    }
    OUT.write_text(json.dumps(report, indent=2) + "\n")
    print(json.dumps(report["headline"], indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
