"""Patch EXPERIMENTS.md §Paper-validation from benchmarks/results/*.json."""
from __future__ import annotations

import json
from pathlib import Path

R = Path(__file__).parent / "results"


def load(name):
    p = R / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def main():
    t2 = load("table2_ppl_kld_imp_pct")
    t3 = load("table3_nps_beats_corpus_pct")
    t5 = load("table5_jaccard_fused_minus_single")
    t6 = load("table6_fused_ppl_imp_pct")
    f4 = load("fig4_best_lambda")
    t1 = load("table1_shortgen_absdiff")
    f5m = load("fig5_measured_decode_speedup")

    def t2_text():
        best_ppl = max(r["imp_ppl_pct"] for r in t2["rows"])
        best_kld = max(r["imp_kld_pct"] for r in t2["rows"])
        return f"✅ up to {best_ppl:.1f}% PPL / {best_kld:.1f}% KLD (I-GLASS strongest, as in the paper)"

    def t3_text():
        pct = t3["derived"]
        mark = "✅" if pct >= 80 else ("≈" if pct >= 60 else "❌")
        return f"{mark} NPS ≤ corpus KLD in {pct:.0f}% of (variant × density) cells"

    def t5_text():
        rows = {r["variant"]: r for r in t5["rows"]}
        lo, gl, fu = rows["local"], rows["global"], rows["fused"]
        beats_local = fu["mean_jaccard"] > lo["mean_jaccard"]
        beats_glob = fu["mean_jaccard"] > gl["mean_jaccard"]
        mark = "✅" if (beats_local and beats_glob) else "◐"
        return (
            f"{mark} fused {fu['mean_jaccard']:.3f}±{fu['std']:.3f} vs local "
            f"{lo['mean_jaccard']:.3f} / global {gl['mean_jaccard']:.3f}"
        )

    def t6_text():
        rows = {r["variant"]: r for r in t6["rows"]}
        fu, lo, gl = rows["fused"], rows["local_only"], rows["global_only"]
        both = fu["ppl"] < lo["ppl"] and fu["ppl"] < gl["ppl"]
        mark = "✅" if both else "◐"
        return (
            f"{mark} fused PPL {fu['ppl']:.3f} vs local {lo['ppl']:.3f} "
            f"({t6['derived']:.1f}% better) / global {gl['ppl']:.3f}"
        )

    def f4_text():
        lam = f4["derived"]
        ppls = [r["ppl"] for r in f4["rows"]]
        smooth = all(abs(ppls[i + 1] - ppls[i]) < 0.6 for i in range(len(ppls) - 1))
        mark = "✅" if 0.3 <= lam <= 0.8 else "◐"
        return f"{mark} smooth={'yes' if smooth else 'no'}, λ* = {lam:.1f}"

    def t1_text():
        return f"✅ mean |acc gap| = {t1['derived']:.3f} (parity)"

    def f5_text():
        return f"✅ {f5m['derived']:.2f}× measured CPU decode-step speedup at 50% (+ residency analysis in §Perf cell 3)"

    reps = {
        "TBD_T2": t2_text() if t2 else "n/a",
        "TBD_T3": t3_text() if t3 else "n/a",
        "TBD_T5": t5_text() if t5 else "n/a",
        "TBD_T6": t6_text() if t6 else "n/a",
        "TBD_F4": f4_text() if f4 else "n/a",
        "TBD_T1": t1_text() if t1 else "n/a",
        "TBD_F5": f5_text() if f5m else "n/a",
    }
    p = Path(__file__).parent.parent / "EXPERIMENTS.md"
    s = p.read_text()
    for k, v in reps.items():
        s = s.replace(k, v)
    p.write_text(s)
    print("\n".join(f"{k}: {v}" for k, v in reps.items()))


if __name__ == "__main__":
    main()
