"""Shared benchmark substrate: tiny trained models, priors, eval prompts.

Everything is cached under benchmarks/.cache (keyed by config) so the table
functions are independently runnable; a fresh run trains two tiny LMs for a
few hundred steps on the synthetic corpus (CPU, ~1 min each).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from functools import partial
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.core import GlassConfig, NPSConfig, build_masks, compute_global_prior
from repro.core.importance import global_activation_stats, global_impact_stats, finalize
from repro.core.nps import teacher_forced_batch
from repro.data.synthetic import CorpusConfig, MixtureCorpus, SyntheticCorpus, shifted_corpus
from repro.data.tokenizer import BOS_ID
from repro.models import ModelConfig, build_model
from repro.models import transformer
from repro.train.loop import TrainConfig, train

CACHE = Path(__file__).parent / ".cache"

TINY_LLAMA = ModelConfig(
    name="bench-llama", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=384, vocab_size=300, ffn_act="silu",
    gated_ffn=True, tie_embeddings=True, dtype="float32", remat="none",
)
TINY_GEMMA = TINY_LLAMA.replace(
    name="bench-gemma", ffn_act="gelu", embed_scale=True, logit_softcap=30.0,
)

NPS_CFG = NPSConfig(n_seqs=48, seq_len=96, batch=16, bos_id=BOS_ID, top_k=20)
TRAIN_STEPS = 600
# the training distribution is a 3-domain mixture: prompt-local statistics
# then reveal the request's domain, which the (domain-averaged) global prior
# cannot — the regime where the paper's local/global fusion matters.
TRAIN_CORPUS = MixtureCorpus(seed=1)


def trained_model(cfg: ModelConfig, steps: int = TRAIN_STEPS):
    """Train (or load cached) tiny model on the synthetic corpus."""
    model = build_model(cfg)
    ckdir = CACHE / f"{cfg.name}-mix-{steps}"
    params_tpl = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    if latest_step(ckdir) is not None:
        _, tree, _ = restore_checkpoint(ckdir, {"params": params_tpl})
        params = jax.tree.map(jnp.asarray, tree["params"])
        return model, params
    out = train(
        model,
        TrainConfig(steps=steps, batch=16, seq=128, log_every=100),
        TRAIN_CORPUS,
        log=lambda s: None,
    )
    save_checkpoint(ckdir, steps, {"params": out["params"]})
    return model, out["params"]


def priors_for(model, params, *, use_cache_key: str) -> Dict[str, jax.Array]:
    """A/I priors from NPS and from the 'external corpus' (shifted synthetic)."""
    ck = CACHE / f"priors-mix-{use_cache_key}"
    tpl = {
        "A_nps": jnp.zeros((model.cfg.n_layers, model.cfg.d_ff)),
        "I_nps": jnp.zeros((model.cfg.n_layers, model.cfg.d_ff)),
        "A_corpus": jnp.zeros((model.cfg.n_layers, model.cfg.d_ff)),
        "I_corpus": jnp.zeros((model.cfg.n_layers, model.cfg.d_ff)),
    }
    if latest_step(ck) is not None:
        _, tree, _ = restore_checkpoint(ck, tpl)
        return jax.tree.map(jnp.asarray, tree)
    rng = jax.random.key(11)
    out = {
        "A_nps": compute_global_prior(model, params, rng, NPS_CFG, "A"),
        "I_nps": compute_global_prior(model, params, rng, NPS_CFG, "I"),
    }
    # corpus prior: teacher-forced batches from the shifted corpus
    corpus = shifted_corpus()
    from repro.data.pipeline import PackedLM

    pipe = PackedLM(corpus, batch=16, seq=NPS_CFG.seq_len)
    batches = [pipe.next_batch() for _ in range(NPS_CFG.n_seqs // 16)]
    batches = [{k: jnp.asarray(v) for k, v in b.items() if k != "mask"} for b in batches]
    out["A_corpus"] = finalize(global_activation_stats(model, params, batches))
    out["I_corpus"] = finalize(global_impact_stats(model, params, batches))
    save_checkpoint(ck, 0, out)
    return out


def eval_prompts(n: int, prompt_len: int = 8, seed: int = 99) -> jax.Array:
    """Short OUT-OF-DISTRIBUTION prompts — the paper's hard regime: its LG
    benchmark (Alpaca) is instruction text, distributionally unlike the
    models' pretraining mix, so prompt-local evidence genuinely differs from
    the global prior.  We mirror that with prompts from the *shifted* corpus
    (different word inventory/statistics than the training corpus)."""
    from repro.data.tokenizer import encode

    rows = []
    i = 10_000 + seed
    while len(rows) < n:
        # held-out documents from ONE domain of the training mixture: short,
        # domain-revealing prompts (the model must commit to that domain)
        ids = encode(TRAIN_CORPUS.domain_document(len(rows) % TRAIN_CORPUS.n_domains, i))
        i += 1
        if len(ids) >= prompt_len:
            rows.append(ids[:prompt_len])
    return jnp.asarray(np.stack(rows), jnp.int32)


@partial(jax.jit, static_argnums=(0, 3))
def _dense_generate_jit(model, params, prompt: jax.Array, max_new: int) -> jax.Array:
    S = prompt.shape[1]
    logits, cache, _ = model.prefill(params, {"tokens": prompt}, S + max_new)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

    def body(carry, i):
        cache, tok = carry
        lg, cache = model.decode_step(params, tok, cache, S + i)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return (cache, nxt), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (cache, tok), jnp.arange(max_new, dtype=jnp.int32))
    return jnp.concatenate([prompt, toks.T], axis=1)


def dense_generate(model, params, prompt: jax.Array, max_new: int) -> jax.Array:
    """Greedy dense continuation of one prompt (1, S) -> (1, S + max_new)."""
    return _dense_generate_jit(model, params, prompt, max_new)


def sparse_eval_logits(
    model, params, full_seq: jax.Array, prompt_len: int,
    prior: Optional[jax.Array], gcfg: Optional[GlassConfig],
) -> jax.Array:
    """Teacher-forced logits under a GLASS mask built from *prompt-only*
    prefill stats (per sample) — or dense when gcfg is None."""
    if gcfg is None:
        return model.logits(params, {"tokens": full_seq})[0]
    _, _, stats = model.prefill(params, {"tokens": full_seq[:, :prompt_len]}, prompt_len + 1)
    masks = build_masks(stats, prior, gcfg)
    return model.logits(params, {"tokens": full_seq}, ffn_masks=masks.mask)[0]


@dataclass
class EvalBundle:
    model: object
    params: object
    priors: Dict[str, jax.Array]
    sequences: List[jax.Array]  # dense trajectories (1, S_total)
    dense_logits: List[jax.Array]
    prompt_len: int


def build_bundle(cfg: ModelConfig, n_samples: int = 16, prompt_len: int = 8, gen_len: int = 48) -> EvalBundle:
    model, params = trained_model(cfg)
    priors = priors_for(model, params, use_cache_key=cfg.name)
    ck = CACHE / f"bundle-mix-{cfg.name}-{n_samples}-{prompt_len}-{gen_len}"
    S_total = prompt_len + gen_len
    tpl = {
        "seqs": jnp.zeros((n_samples, S_total), jnp.int32),
        "logits": jnp.zeros((n_samples, S_total, cfg.vocab_size)),
    }
    if latest_step(ck) is not None:
        _, tree, _ = restore_checkpoint(ck, tpl)
        seqs = [jnp.asarray(tree["seqs"][i : i + 1]) for i in range(n_samples)]
        dls = [jnp.asarray(tree["logits"][i]) for i in range(n_samples)]
        return EvalBundle(model, params, priors, seqs, dls, prompt_len)
    prompts = eval_prompts(n_samples, prompt_len)
    seqs, dls = [], []
    for i in range(n_samples):
        seq = dense_generate(model, params, prompts[i : i + 1], gen_len)
        seqs.append(seq)
        dls.append(model.logits(params, {"tokens": seq})[0])
    save_checkpoint(
        ck, 0,
        {"seqs": jnp.concatenate(seqs, 0), "logits": jnp.stack(dls)},
    )
    return EvalBundle(model, params, priors, seqs, dls, prompt_len)
