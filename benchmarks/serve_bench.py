"""Serving throughput: static batching vs continuous batching.

Workload: N requests with one shared prompt length, Poisson arrivals (in
decode-step ticks), and widely varying generation lengths — the regime the
paper's per-prompt GLASS selection targets and the one where static batching
loses: a static batch decodes until its LONGEST member finishes, so short
requests burn arena slots doing nothing, and every batch waits for its last
arrival before starting.

Both engines serve identical requests with identical (random-init) weights:

  * static      — the original ``Engine``: requests grouped into batches of
                  ``max_slots`` in arrival order; each batch runs
                  max(max_new) steps for everyone;
  * continuous  — ``ContinuousEngine``: admit-as-slots-free, per-slot GLASS
                  state, evict on completion.

Reported per engine, all post-warmup (engines are reused so every jit cache
is hot — a cold pass would mostly measure compilation):

  * useful tokens/sec — wall-clock.  CAVEAT: on this CPU micro-model the
    static engine fuses each whole trajectory into one XLA scan with zero
    host round-trips, while the continuous engine pays a host scheduling
    round-trip per decode chunk; at real model sizes per-step device compute
    dominates and this inversion disappears.  The scheduling quality itself
    is captured by the two hardware-independent metrics:
  * mean completion latency in decode-step ticks on a shared virtual
    timeline (static batches start at max(member arrivals, previous batch
    end));
  * slot-steps per useful token — arena occupancy burned per token emitted
    (1.0 is perfect; static wastes slots holding short requests until the
    batch's longest member finishes).

    PYTHONPATH=src:. python benchmarks/serve_bench.py
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GlassConfig
from repro.models import ModelConfig, build_model
from repro.serve.engine import ContinuousEngine, Engine
from repro.serve.scheduler import Request

CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=384, vocab_size=300, ffn_act="silu",
    gated_ffn=True, tie_embeddings=True, dtype="float32", remat="none",
)

N_REQUESTS = 24
MAX_SLOTS = 4
PROMPT_LEN = 8
MAX_LEN = 48
ARRIVAL_RATE = 0.5  # mean requests per decode tick


def _workload(seed: int = 0) -> List[Request]:
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE, size=N_REQUESTS)).astype(int)
    new = rng.randint(4, 33, size=N_REQUESTS)  # short and long generations mixed
    return [
        Request(
            uid=i,
            prompt=rng.randint(3, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new=int(new[i]),
            arrival=int(arrivals[i]),
        )
        for i in range(N_REQUESTS)
    ]


def _static_serve(eng: Engine, reqs: List[Request]):
    """Arrival-order batches of MAX_SLOTS through the static Engine.

    Returns (wall_s, mean_latency_steps): wall time of the generate calls;
    latency on the virtual step timeline (batch waits for its last arrival
    and for the previous batch's slots)."""
    wall = 0.0
    latencies = []
    t_virtual = 0
    slot_steps = 0
    for i in range(0, len(reqs), MAX_SLOTS):
        batch = reqs[i : i + MAX_SLOTS]
        prompts = jnp.asarray(np.stack([r.prompt for r in batch]))
        steps = max(r.max_new for r in batch)
        t0 = time.perf_counter()
        res = eng.generate(prompts, max_new=steps)
        jax.block_until_ready(res.tokens)
        wall += time.perf_counter() - t0
        slot_steps += len(batch) * steps
        start = max(t_virtual, max(r.arrival for r in batch))
        t_virtual = start + steps
        latencies += [t_virtual - r.arrival for r in batch]
    return wall, float(np.mean(latencies)), slot_steps


def _continuous_serve(eng: ContinuousEngine, reqs: List[Request]):
    # replay the arrival pattern relative to the engine's current tick, so a
    # warmed engine serves the identical schedule it compiled for
    base = eng.t
    ss0 = eng.slot_steps
    wave = [Request(r.uid, r.prompt, r.max_new, base + r.arrival) for r in reqs]
    t0 = time.perf_counter()
    done = eng.run(wave)
    jax.block_until_ready(eng.pool.cache)
    wall = time.perf_counter() - t0
    lat = float(np.mean([f.finished_step - f.arrival for f in done.values()]))
    return wall, lat, eng.slot_steps - ss0


def serve_throughput() -> Tuple[List[dict], float]:
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    prior = jnp.abs(jax.random.normal(jax.random.key(1), (CFG.n_layers, CFG.d_ff)))
    reqs = _workload()
    useful_tokens = sum(r.max_new for r in reqs)

    engines = {
        "static": (Engine(model, params, glass=GlassConfig(density=0.5),
                          global_prior=prior), _static_serve),
        "continuous": (ContinuousEngine(model, params, max_slots=MAX_SLOTS,
                                        max_len=MAX_LEN, glass=GlassConfig(density=0.5),
                                        global_prior=prior), _continuous_serve),
    }
    rows = []
    for name, (eng, fn) in engines.items():
        fn(eng, reqs)  # warmup on the SAME instance: jit caches stay hot
        wall, lat, slot_steps = fn(eng, reqs)
        rows.append(
            dict(
                engine=name,
                tokens_per_s=useful_tokens / wall,
                wall_s=wall,
                mean_latency_steps=lat,
                slot_steps_per_token=slot_steps / useful_tokens,
                useful_tokens=useful_tokens,
            )
        )
    latency_speedup = rows[0]["mean_latency_steps"] / rows[1]["mean_latency_steps"]
    return rows, latency_speedup


if __name__ == "__main__":
    rows, latency_speedup = serve_throughput()
    print(f"{'engine':12s} {'tok/s':>10s} {'wall_s':>8s} {'latency(steps)':>15s} {'slot-steps/tok':>15s}")
    for r in rows:
        print(
            f"{r['engine']:12s} {r['tokens_per_s']:10.1f} {r['wall_s']:8.3f} "
            f"{r['mean_latency_steps']:15.1f} {r['slot_steps_per_token']:15.2f}"
        )
    print(f"continuous vs static: {latency_speedup:.2f}x lower mean completion latency, "
          f"{rows[0]['slot_steps_per_token'] / rows[1]['slot_steps_per_token']:.2f}x less "
          f"arena occupancy per token")
