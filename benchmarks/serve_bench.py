"""Serving benchmark: static vs continuous vs paged batching, plus a
latency-SLO sweep, with machine-readable output (``BENCH_serve.json``).

Workload: N requests with one shared prompt length, Poisson arrivals (in
decode-step ticks), and widely varying generation lengths — the regime the
paper's per-prompt GLASS selection targets and the one where static batching
loses: a static batch decodes until its LONGEST member finishes, so short
requests burn arena slots doing nothing, and every batch waits for its last
arrival before starting.

Engines serve identical requests with identical (random-init) weights:

  * static      — the original ``Engine``: requests grouped into batches of
                  ``max_slots`` in arrival order; each batch runs
                  max(max_new) steps for everyone;
  * continuous  — ``ContinuousEngine``: admit-as-slots-free, per-slot GLASS
                  state, evict on completion; fixed slot-arena KV;
  * paged       — ``PagedEngine``: same scheduling, but KV lives in a
                  shared block pool (a request holds ceil(rows/block)
                  blocks, not a max_len row) and prompts prefill in bounded
                  chunks interleaved with decode.

Reported per engine, all post-warmup (engines are reused so every jit cache
is hot — a cold pass would mostly measure compilation):

  * useful tokens/sec — wall-clock.  CAVEAT: on this CPU micro-model the
    static engine fuses each whole trajectory into one XLA scan with zero
    host round-trips, while the continuous/paged engines pay a host
    scheduling round-trip per decode chunk; at real model sizes per-step
    device compute dominates and this inversion disappears.  The scheduling
    quality itself is captured by the hardware-independent metrics:
  * completion latency in decode-step ticks (mean / p50 / p99) on a shared
    virtual timeline (static batches start at max(member arrivals, previous
    batch end));
  * slot-steps per useful token — occupancy burned per token emitted;
  * KV rows x ticks per useful token — *allocated* cache memory integrated
    over time: the slot arena always holds max_slots x max_len rows, the
    block pool only ceil(len/block) blocks per in-flight request.

The latency-SLO sweep re-runs continuous vs paged across arrival rates and
reports p50/p99 completion latency per rate (deterministic in ticks, so no
warmup needed).

The *pressure* scenario offers arrival rate > pool capacity to a paged
engine whose block pool is sized well below the worst case, comparing
``alloc_mode="full"`` (PR-2 full-need admission: requests queue until their
whole footprint fits) against ``alloc_mode="incremental"`` (allocate on
block boundaries, preempt with swap/recompute under pressure).  Reported
per mode: preemption counts (swap / recompute), bytes swapped to host,
tokens recomputed, admission-latency mean/p50/p99, and completion latency —
the incremental engine should admit strictly earlier at a modest
recompute/swap cost.

Tick-accounting caveat: the continuous engine prefills out-of-band (a
prompt costs zero ticks), while the paged engine charges one tick per
prefill chunk — so its latency numbers carry an honest admission cost the
slot arena hides.  The comparison favors continuous on latency by
construction; the paged win is the KV-rows column.

The *speculative* scenario sweeps (draft_ratio, spec_k) settings of the
self-speculative decoder (same weights under an aggressive GLASS draft
tier propose k tokens; the target tier verifies all k+1 positions in one
forced-token scan) and reports draft acceptance rate, accepted
tokens/tick, and rollback counts — with a token-identity cross-check
against the plain paged engine, because speculation must be invisible in
the streams.

The *mixed-policy* scenario drives the per-request generation API
(``add_request`` + streaming ``step()``): greedy, seeded-sampled, reduced
per-request GLASS density, and speculative requests share one batch, with
two determinism cross-checks — full-replay bit-identity and
schedule-invariance of the seeded streams (the counter-based PRNG keys
every draw on (request seed, generated position), so batch composition is
invisible).

The *cluster* scenario shards the paged engine into N replicas behind one
global queue (``serve.cluster.ClusterEngine``) and serves a hot-spot
workload — bursts alternating heavy (long-generation) and light requests,
the adversarial case for round-robin assignment, which parks every heavy
on the same replica.  Three setups at equal TOTAL capacity: one big
single engine (N x blocks/slots), a cost-scored ``balanced`` cluster
(pending-token load + block-overflow penalty − prefix-affinity credit,
hot-spot migration enabled), and a naive ``round_robin`` cluster.
Reported per setup: drain ticks, admission-wait p99, migration
count/bytes, per-replica occupancy variance — with the balanced streams
cross-checked bit-identical against the single engine (replica sharding
and migration must be invisible in the tokens), and balanced admission
p99 beating round-robin asserted in CI.

The *shared-prefix* scenario fans N requests out over one system-prompt
style shared prefix with a prefix-cached vs uncached paged engine:
cache-hit admissions resume prefill at the fork point from registered KV
blocks (one physical copy, refcounted copy-on-write tables), reporting
hit rate, prefill-tokens-saved, and allocated-KV-rows x ticks per token —
with a bit-identity cross-check against the uncached streams.

    PYTHONPATH=src:. python benchmarks/serve_bench.py
"""
from __future__ import annotations

import json
import time
from dataclasses import replace
from pathlib import Path
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GlassConfig, GlassParams
from repro.models import ModelConfig, build_model
from repro.serve.cluster import ClusterEngine, MigrationConfig
from repro.serve.engine import ContinuousEngine, Engine, PagedEngine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request

CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=384, vocab_size=300, ffn_act="silu",
    gated_ffn=True, tie_embeddings=True, dtype="float32", remat="none",
)

N_REQUESTS = 24
MAX_SLOTS = 4
PROMPT_LEN = 8
MAX_LEN = 48
BLOCK_SIZE = 8
CHUNK_TOKENS = 4
ARRIVAL_RATE = 0.5  # mean requests per decode tick
SWEEP_RATES = (0.25, 0.5, 1.0)
GLASS = GlassConfig(density=0.5)
OUT_JSON = Path(__file__).with_name("BENCH_serve.json")

# pressure scenario: arrivals outrun a deliberately undersized block pool;
# slots are ample so BLOCKS are the binding constraint (full-need admission
# can hold ~2.4 worst-case requests, incremental starts one per block)
PRESSURE_RATE = 2.0
PRESSURE_REQUESTS = 16
PRESSURE_SLOTS = 6
PRESSURE_BLOCKS = 13  # 12 usable: ~2.4 full-need requests' worth

# speculative scenario: (draft_ratio, spec_k) sweep — the draft tier keeps
# density * draft_ratio of the FFN, k tokens drafted per round
SPEC_SETTINGS = ((0.5, 2), (0.25, 4))

# cluster scenario: N replica shards vs one big engine at equal TOTAL
# capacity; heavy/light bursts make round-robin park every heavy request
# on the same replica
CLUSTER_REPLICAS = 2
CLUSTER_SLOTS = 2  # per replica; the single engine gets N x this
CLUSTER_BLOCKS = 10  # per replica; the single engine gets N x this
CLUSTER_HEAVY_NEW = 28
CLUSTER_LIGHT_NEW = 4
CLUSTER_BURSTS = 6
CLUSTER_BURST_GAP = 2  # cluster ticks between burst arrivals


def _workload(arrival_rate: float, seed: int = 0) -> List[Request]:
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=N_REQUESTS)).astype(int)
    new = rng.randint(4, 33, size=N_REQUESTS)  # short and long generations mixed
    return [
        Request(
            uid=i,
            prompt=rng.randint(3, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new=int(new[i]),
            arrival=int(arrivals[i]),
        )
        for i in range(N_REQUESTS)
    ]


def _pcts(latencies) -> dict:
    a = np.asarray(latencies, np.float64)
    return dict(
        mean_latency_steps=float(a.mean()),
        p50_latency_steps=float(np.percentile(a, 50)),
        p99_latency_steps=float(np.percentile(a, 99)),
    )


def _static_serve(eng: Engine, reqs: List[Request]):
    """Arrival-order batches of MAX_SLOTS through the static Engine.

    Latency on the virtual step timeline: a batch waits for its last
    arrival and for the previous batch's slots."""
    wall = 0.0
    latencies = []
    t_virtual = 0
    slot_steps = 0
    for i in range(0, len(reqs), MAX_SLOTS):
        batch = reqs[i : i + MAX_SLOTS]
        prompts = jnp.asarray(np.stack([r.prompt for r in batch]))
        steps = max(r.max_new for r in batch)
        t0 = time.perf_counter()
        res = eng.generate(prompts, max_new=steps)
        jax.block_until_ready(res.tokens)
        wall += time.perf_counter() - t0
        slot_steps += len(batch) * steps
        start = max(t_virtual, max(r.arrival for r in batch))
        t_virtual = start + steps
        latencies += [t_virtual - r.arrival for r in batch]
    return wall, latencies, slot_steps, None


def _queue_serve(eng, reqs: List[Request]):
    """Shared path for ContinuousEngine / PagedEngine: replay the arrival
    pattern relative to the engine's current tick, so a warmed engine serves
    the identical schedule it compiled for."""
    base = eng.t
    ss0 = eng.slot_steps
    wave = [
        Request(r.uid, r.prompt, r.max_new, base + r.arrival, r.priority, r.deadline)
        for r in reqs
    ]
    t0 = time.perf_counter()
    done = eng.run(wave)
    jax.block_until_ready(eng.pool.cache)
    wall = time.perf_counter() - t0
    latencies = [f.finished_step - f.arrival for f in done.values()]
    ticks = eng.t - base
    if isinstance(eng, PagedEngine):
        row_ticks = eng.kv_row_ticks  # cumulative; caller diffs
    else:
        row_ticks = eng.pool.max_slots * eng.pool.max_len * ticks
    return wall, latencies, eng.slot_steps - ss0, row_ticks


def _pressure_workload(seed: int = 2) -> List[Request]:
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(
        rng.exponential(1.0 / PRESSURE_RATE, size=PRESSURE_REQUESTS)
    ).astype(int)
    new = rng.randint(4, 29, size=PRESSURE_REQUESTS)
    return [
        Request(
            uid=i,
            prompt=rng.randint(3, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new=int(new[i]),
            arrival=int(arrivals[i]),
        )
        for i in range(PRESSURE_REQUESTS)
    ]


def pressure_scenario(model, params, prior) -> dict:
    """Arrival rate > capacity: full-need admission vs incremental
    allocation with swap/recompute preemption, on an undersized pool.
    Deterministic in ticks; also cross-checks zero token divergence."""
    reqs = _pressure_workload()
    rows = {}
    outs = {}
    for mode in ("full", "incremental"):
        eng = PagedEngine(
            model, params, max_slots=PRESSURE_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, num_blocks=PRESSURE_BLOCKS,
            chunk_tokens=CHUNK_TOKENS, glass=GLASS, global_prior=prior,
            alloc_mode=mode,
        )
        done = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs])
        outs[mode] = done
        waits = np.asarray(eng.admission_waits, np.float64)
        lat = np.asarray(
            [f.finished_step - f.arrival for f in done.values()], np.float64
        )
        rows[mode] = dict(
            alloc_mode=mode,
            preemptions=eng.preempt_count,
            swaps=eng.lc.preempted(kind="swap"),
            recomputes=eng.lc.preempted(kind="recompute"),
            swap_bytes=eng.swap_bytes,
            recompute_tokens=eng.recompute_tokens,
            admission_wait_mean=float(waits.mean()),
            admission_wait_p50=float(np.percentile(waits, 50)),
            admission_wait_p99=float(np.percentile(waits, 99)),
            mean_latency_steps=float(lat.mean()),
            p99_latency_steps=float(np.percentile(lat, 99)),
            drain_ticks=eng.t,
        )
    for r in reqs:  # preemption must not change a single token
        np.testing.assert_array_equal(
            outs["full"][r.uid].tokens, outs["incremental"][r.uid].tokens
        )
    return dict(
        config=dict(
            arrival_rate=PRESSURE_RATE, n_requests=PRESSURE_REQUESTS,
            num_blocks=PRESSURE_BLOCKS, block_size=BLOCK_SIZE,
            max_slots=PRESSURE_SLOTS, chunk_tokens=CHUNK_TOKENS,
        ),
        modes=list(rows.values()),
        admission_wait_saving=(
            rows["full"]["admission_wait_mean"]
            / max(rows["incremental"]["admission_wait_mean"], 1e-9)
        ),
    )


def speculative_scenario(model, params, prior) -> dict:
    """Self-speculative decode: acceptance rate x tokens/tick across
    (draft_ratio, spec_k) settings vs the plain paged engine, on one
    workload.  Deterministic in ticks; cross-checks zero token divergence
    (the rollback machinery must be invisible in the streams).

    Tick-accounting note: a speculative round is ONE engine tick but runs
    2k+1 scan steps (k draft + k+1 verify), so ``drain_ticks`` shrinking
    with acceptance is the scheduling win while ``slot_steps`` carries the
    honest compute cost — on hardware where the draft tier's compact
    weights stream proportionally less HBM, the step cost ratio follows
    the density ratio, which is what makes the trade profitable."""
    reqs = _workload(ARRIVAL_RATE, seed=4)
    base = PagedEngine(
        model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
        block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
        glass=GLASS, global_prior=prior,
    )
    ref = base.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs])
    rows = [dict(setting="plain", draft_ratio=None, spec_k=0,
                 drain_ticks=base.t, slot_steps=base.slot_steps)]
    for dr, k in SPEC_SETTINGS:
        eng = PagedEngine(
            model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
            glass=replace(GLASS, draft_ratio=dr),
            global_prior=prior, spec_k=k,
        )
        done = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs])
        for r in reqs:  # speculation must not change a single token
            np.testing.assert_array_equal(ref[r.uid].tokens, done[r.uid].tokens)
        t = eng.spec_telemetry
        rows.append(dict(
            setting=f"dr{dr}_k{k}", draft_ratio=dr, spec_k=k,
            drain_ticks=eng.t, slot_steps=eng.slot_steps,
            draft_acceptance_rate=t["draft_acceptance_rate"],
            accepted_tokens_per_tick=t["accepted_tokens_per_tick"],
            rollbacks=t["rollbacks"],
            rolled_back_rows=t["rolled_back_rows"],
            spec_ticks=t["spec_ticks"],
            drafted_tokens=t["drafted_tokens"],
            accepted_tokens=t["accepted_tokens"],
        ))
    return dict(
        config=dict(settings=[list(s) for s in SPEC_SETTINGS],
                    density=GLASS.density, n_requests=len(reqs)),
        settings=rows,
    )


def shared_prefix_scenario(model, params, prior) -> dict:
    """Prefix caching: N requests over one shared 16-token prefix (a
    system-prompt-style workload — the first request warms the cache, the
    fan-out hits it).  Cached vs uncached paged engines serve the
    identical workload; the cache must be invisible in the streams (the
    fork-aligned resume is bit-identical) while saving prefill work and
    allocated-KV-rows x ticks (shared blocks are counted once: refcounted
    copy-on-write tables hold ONE physical copy of the prefix)."""
    rng = np.random.RandomState(9)
    n = 12
    shared = rng.randint(3, CFG.vocab_size, size=16).astype(np.int32)
    # the warming request arrives alone; the fan-out lands after its
    # prefill (5 chunks) has registered the full shared chain
    gaps = np.cumsum(rng.exponential(1.0, size=n - 1)).astype(int)
    arrivals = [0] + [8 + int(g) for g in gaps]
    reqs = [
        Request(
            uid=i,
            prompt=np.concatenate(
                [shared, rng.randint(3, CFG.vocab_size, size=4).astype(np.int32)]
            ),
            max_new=8,
            arrival=arrivals[i],
        )
        for i in range(n)
    ]
    useful = sum(r.max_new for r in reqs)
    total_prompt = sum(len(r.prompt) for r in reqs)
    rows = {}
    outs = {}
    for cached in (False, True):
        eng = PagedEngine(
            model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
            glass=GLASS, global_prior=prior, prefix_cache=cached,
        )
        done = eng.run([Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs])
        outs[cached] = done
        row = dict(
            prefix_cache=cached,
            drain_ticks=eng.t,
            kv_row_ticks_per_token=eng.kv_row_ticks / useful,
            peak_kv_rows=eng.pool.peak_blocks * eng.pool.block_size,
        )
        if cached:
            pc = eng.pool.prefix_cache
            row.update(
                hits=pc.hits, misses=pc.misses, hit_rate=pc.hit_rate,
                prefill_tokens_saved=pc.tokens_saved,
                prefill_tokens_saved_frac=pc.tokens_saved / total_prompt,
                evictions=pc.evictions, inserts=pc.inserts,
            )
        rows[cached] = row
    for r in reqs:  # the cache must be invisible in the streams
        np.testing.assert_array_equal(
            outs[False][r.uid].tokens, outs[True][r.uid].tokens
        )
    return dict(
        config=dict(
            n_requests=n, shared_prefix_len=len(shared), tail_len=4,
            max_new=8, block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
            max_slots=MAX_SLOTS,
        ),
        modes=[rows[False], rows[True]],
        headline=dict(
            hit_rate=rows[True]["hit_rate"],
            prefill_tokens_saved_frac=rows[True]["prefill_tokens_saved_frac"],
            kv_row_ticks_saving_cached_vs_uncached=(
                rows[False]["kv_row_ticks_per_token"]
                / max(rows[True]["kv_row_ticks_per_token"], 1e-9)
            ),
            peak_kv_rows_saving=(
                rows[False]["peak_kv_rows"] / max(rows[True]["peak_kv_rows"], 1)
            ),
        ),
    )


def _hotspot_workload(seed: int = 11):
    """Bursts alternating heavy (long-generation) and light requests —
    with N=2 replicas, round-robin sends every heavy to replica 0 and
    every light to replica 1, the textbook hot spot."""
    rng = np.random.RandomState(seed)
    reqs = []
    for burst in range(CLUSTER_BURSTS):
        for j in range(2 * CLUSTER_REPLICAS):
            heavy = j % 2 == 0
            reqs.append(Request(
                uid=len(reqs),
                prompt=rng.randint(3, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32),
                max_new=CLUSTER_HEAVY_NEW if heavy else CLUSTER_LIGHT_NEW,
                arrival=burst * CLUSTER_BURST_GAP,
            ))
    return reqs


def cluster_scenario(model, params, prior) -> dict:
    """Replica-sharded serving: one global queue over N PagedEngine
    replicas vs ONE engine with the replicas' combined capacity, on the
    hot-spot workload.  Cost-scored (balanced) admission spreads the
    heavies; round-robin does not — balanced must beat it on admission
    wait p99 (the CI-asserted headline).  The balanced cluster runs with
    hot-spot migration enabled, and its streams must equal the single
    engine's bit-for-bit: replica sharding, cost routing, and cross-pool
    migration are scheduling moves, never token changes."""
    reqs = _hotspot_workload()
    single = PagedEngine(
        model, params, max_slots=CLUSTER_REPLICAS * CLUSTER_SLOTS, max_len=MAX_LEN,
        block_size=BLOCK_SIZE, num_blocks=CLUSTER_REPLICAS * CLUSTER_BLOCKS,
        chunk_tokens=CHUNK_TOKENS, glass=GLASS, global_prior=prior,
    )
    done_single = single.run(
        [Request(r.uid, r.prompt, r.max_new, r.arrival) for r in reqs]
    )
    waits = np.asarray(single.admission_waits, np.float64)
    rows = [dict(
        setup="single", drain_ticks=single.t,
        admission_wait_p99=float(np.percentile(waits, 99)),
        migrations=0, migration_bytes=0, occupancy_variance=0.0,
    )]
    outs = {}
    # round_robin is the NAIVE baseline (no migration); rr_migrate shows
    # the migration policy rescuing the bad placement after the fact
    setups = (
        ("balanced", "balanced", True),
        ("round_robin", "round_robin", False),
        ("rr_migrate", "round_robin", True),
    )
    for setup, admission, migrate in setups:
        cl = ClusterEngine(
            model, params, n_replicas=CLUSTER_REPLICAS, admission=admission,
            migration=MigrationConfig(enabled=migrate),
            max_slots=CLUSTER_SLOTS, max_len=MAX_LEN, block_size=BLOCK_SIZE,
            num_blocks=CLUSTER_BLOCKS, chunk_tokens=CHUNK_TOKENS,
            glass=GLASS, global_prior=prior,
        )
        for r in reqs:
            cl.add_request(r.prompt, r.max_new, uid=r.uid, arrival=r.arrival)
        outs[setup] = cl.run()
        t = cl.telemetry()
        rows.append(dict(
            setup=setup, drain_ticks=t["drain_ticks"],
            admission_wait_p99=t["admission_wait_p99"],
            migrations=t["migrations"], migration_bytes=t["migration_bytes"],
            occupancy_variance=t["occupancy_variance"],
            per_replica=t["per_replica"],
        ))
    for r in reqs:  # sharding + migration must not change a single token
        for setup in outs:
            np.testing.assert_array_equal(
                done_single[r.uid].tokens, outs[setup][r.uid].tokens
            )
    by = {r["setup"]: r for r in rows}
    return dict(
        config=dict(
            n_replicas=CLUSTER_REPLICAS, slots_per_replica=CLUSTER_SLOTS,
            blocks_per_replica=CLUSTER_BLOCKS, bursts=CLUSTER_BURSTS,
            burst_gap=CLUSTER_BURST_GAP, heavy_new=CLUSTER_HEAVY_NEW,
            light_new=CLUSTER_LIGHT_NEW, n_requests=len(reqs),
        ),
        setups=rows,
        headline=dict(
            balanced_wait_p99=by["balanced"]["admission_wait_p99"],
            round_robin_wait_p99=by["round_robin"]["admission_wait_p99"],
            wait_p99_saving_balanced_vs_rr=(
                by["round_robin"]["admission_wait_p99"]
                / max(by["balanced"]["admission_wait_p99"], 1e-9)
            ),
            occupancy_variance_saving=(
                by["round_robin"]["occupancy_variance"]
                / max(by["balanced"]["occupancy_variance"], 1e-9)
            ),
            migrations_rescuing_rr=by["rr_migrate"]["migrations"],
            migration_bytes=by["rr_migrate"]["migration_bytes"],
            streams_identical_to_single=True,
        ),
    )


def mixed_policy_scenario(model, params, prior) -> dict:
    """Per-request generation API: greedy + seeded-sampled + two GLASS
    densities + speculative requests in ONE PagedEngine batch (the
    vLLM-style ``add_request``/``step`` frontend), consumed as streaming
    RequestOutput deltas.

    Reported: per-policy token counts, drain ticks, speculative telemetry
    for the spec_k>0 slice, and two determinism cross-checks — a full
    re-run reproduces every stream bit-identically (``replay_identical``:
    counter-based PRNG keyed on (seed, position)), and each seeded stream
    equals single-request serving (``schedule_invariant``: batch
    composition is invisible to a request's sample draws)."""
    rng = np.random.RandomState(7)
    n = 12
    prompts = [rng.randint(3, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32)
               for _ in range(n)]
    new = rng.randint(6, 25, size=n)
    policies = []
    for i in range(n):
        kind = ("greedy", "sampled", "sampled_low", "spec")[i % 4]
        if kind == "greedy":
            policies.append((kind, None, None))
        elif kind == "sampled":
            policies.append((kind, SamplingParams(temperature=0.9, top_k=40,
                                                  seed=1000 + i), None))
        elif kind == "sampled_low":
            policies.append((kind, SamplingParams(temperature=1.1, seed=2000 + i),
                             GlassParams(density=GLASS.density / 2, spec_k=0)))
        else:
            policies.append((kind, None, GlassParams(spec_k=2)))

    def mk_engine():
        return PagedEngine(
            model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
            glass=replace(GLASS, draft_ratio=0.5), global_prior=prior,
        )

    def serve(eng, which=None):
        outs, deltas = {}, 0
        for i in (range(n) if which is None else which):
            kind, sp, gp = policies[i]
            eng.add_request(prompts[i], int(new[i]), uid=i, sampling=sp, glass=gp)
        while eng._work_remaining():
            for o in eng.step():
                if o.finished:
                    outs[o.uid] = o
                else:
                    deltas += len(o.new_tokens)
        return outs, deltas

    eng = mk_engine()
    t0 = time.perf_counter()
    outs, deltas = serve(eng)
    wall = time.perf_counter() - t0
    # determinism cross-check 1: a fresh engine replays every stream
    outs2, _ = serve(mk_engine())
    replay_identical = all(
        np.array_equal(outs[i].tokens, outs2[i].tokens) for i in range(n)
    )
    # determinism cross-check 2: seeded streams are schedule-invariant
    schedule_invariant = True
    for i in range(n):
        if policies[i][0].startswith("sampled"):
            solo, _ = serve(mk_engine(), which=[i])
            schedule_invariant &= np.array_equal(outs[i].tokens, solo[i].tokens)
    by_kind: dict = {}
    for i in range(n):
        k = policies[i][0]
        by_kind[k] = by_kind.get(k, 0) + int(outs[i].tokens.shape[0])
    t = eng.spec_telemetry
    return dict(
        config=dict(n_requests=n, densities=[GLASS.density, GLASS.density / 2],
                    spec_k=2, draft_ratio=0.5),
        tokens_by_policy=by_kind,
        streamed_delta_tokens=deltas,
        drain_ticks=eng.t,
        wall_s=wall,
        finish_reasons=sorted({o.finish_reason for o in outs.values()}),
        spec_ticks=t["spec_ticks"],
        draft_acceptance_rate=t["draft_acceptance_rate"],
        replay_identical=bool(replay_identical),
        schedule_invariant=bool(schedule_invariant),
    )


def serve_throughput() -> Tuple[List[dict], dict]:
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    prior = jnp.abs(jax.random.normal(jax.random.key(1), (CFG.n_layers, CFG.d_ff)))
    reqs = _workload(ARRIVAL_RATE)
    useful_tokens = sum(r.max_new for r in reqs)

    def mk_paged():
        return PagedEngine(
            model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            block_size=BLOCK_SIZE, chunk_tokens=CHUNK_TOKENS,
            glass=GLASS, global_prior=prior,
        )

    engines = {
        "static": (Engine(model, params, glass=GLASS, global_prior=prior), _static_serve),
        "continuous": (
            ContinuousEngine(model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                             glass=GLASS, global_prior=prior),
            _queue_serve,
        ),
        "paged": (mk_paged(), _queue_serve),
    }
    rows = []
    for name, (eng, fn) in engines.items():
        fn(eng, reqs)  # warmup on the SAME instance: jit caches stay hot
        rt0 = eng.kv_row_ticks if isinstance(eng, PagedEngine) else None
        wall, latencies, slot_steps, row_ticks = fn(eng, reqs)
        if isinstance(eng, PagedEngine):
            row_ticks = eng.kv_row_ticks - rt0
        row = dict(
            engine=name,
            tokens_per_s=useful_tokens / wall,
            wall_s=wall,
            slot_steps_per_token=slot_steps / useful_tokens,
            useful_tokens=useful_tokens,
            **_pcts(latencies),
        )
        if row_ticks is not None:
            row["kv_row_ticks_per_token"] = row_ticks / useful_tokens
        if isinstance(eng, PagedEngine):
            row["peak_kv_rows"] = eng.pool.peak_blocks * eng.pool.block_size
            row["arena_kv_rows"] = eng.pool.max_slots * eng.pool.max_len
        rows.append(row)

    # latency-SLO sweep: arrival rate vs p50/p99 (deterministic in ticks)
    sweep = []
    cont = ContinuousEngine(model, params, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            glass=GLASS, global_prior=prior)
    paged = mk_paged()
    for rate in SWEEP_RATES:
        wave = _workload(rate, seed=1)
        for name, eng in (("continuous", cont), ("paged", paged)):
            _, latencies, _, _ = _queue_serve(eng, wave)
            sweep.append(dict(engine=name, arrival_rate=rate, **_pcts(latencies)))

    pressure = pressure_scenario(model, params, prior)
    speculative = speculative_scenario(model, params, prior)
    mixed_policy = mixed_policy_scenario(model, params, prior)
    shared_prefix = shared_prefix_scenario(model, params, prior)
    cluster = cluster_scenario(model, params, prior)

    by = {r["engine"]: r for r in rows}
    headline = dict(
        latency_speedup_continuous_vs_static=(
            by["static"]["mean_latency_steps"] / by["continuous"]["mean_latency_steps"]
        ),
        slot_step_saving_continuous_vs_static=(
            by["static"]["slot_steps_per_token"] / by["continuous"]["slot_steps_per_token"]
        ),
        kv_saving_paged_vs_continuous=(
            by["continuous"]["kv_row_ticks_per_token"] / by["paged"]["kv_row_ticks_per_token"]
        ),
        paged_latency_overhead_vs_continuous=(
            by["paged"]["mean_latency_steps"] / by["continuous"]["mean_latency_steps"]
        ),
    )
    return rows, dict(
        config=dict(
            model=CFG.name, n_requests=N_REQUESTS, max_slots=MAX_SLOTS,
            prompt_len=PROMPT_LEN, max_len=MAX_LEN, block_size=BLOCK_SIZE,
            chunk_tokens=CHUNK_TOKENS, arrival_rate=ARRIVAL_RATE,
            glass_density=GLASS.density,
        ),
        engines=rows,
        slo_sweep=sweep,
        pressure=pressure,
        speculative=speculative,
        mixed_policy=mixed_policy,
        shared_prefix=shared_prefix,
        cluster=cluster,
        headline=headline,
    )


if __name__ == "__main__":
    rows, report = serve_throughput()
    hdr = f"{'engine':12s} {'tok/s':>9s} {'wall_s':>8s} {'lat mean':>9s} {'p50':>7s} {'p99':>7s} {'ss/tok':>7s} {'kvrows/tok':>11s}"
    print(hdr)
    for r in rows:
        print(
            f"{r['engine']:12s} {r['tokens_per_s']:9.1f} {r['wall_s']:8.3f} "
            f"{r['mean_latency_steps']:9.1f} {r['p50_latency_steps']:7.1f} "
            f"{r['p99_latency_steps']:7.1f} {r['slot_steps_per_token']:7.2f} "
            f"{r.get('kv_row_ticks_per_token', float('nan')):11.1f}"
        )
    h = report["headline"]
    print(
        f"continuous vs static: {h['latency_speedup_continuous_vs_static']:.2f}x lower mean "
        f"completion latency, {h['slot_step_saving_continuous_vs_static']:.2f}x less occupancy/token"
    )
    print(
        f"paged vs continuous:  {h['kv_saving_paged_vs_continuous']:.2f}x less allocated KV "
        f"memory/token at {h['paged_latency_overhead_vs_continuous']:.2f}x the mean latency"
    )
    print("\nSLO sweep (arrival rate -> completion latency):")
    for s in report["slo_sweep"]:
        print(
            f"  rate={s['arrival_rate']:.2f} {s['engine']:12s} "
            f"p50={s['p50_latency_steps']:7.1f} p99={s['p99_latency_steps']:7.1f}"
        )
    print("\npressure (arrival rate > pool capacity):")
    for m in report["pressure"]["modes"]:
        print(
            f"  {m['alloc_mode']:12s} admit wait mean={m['admission_wait_mean']:6.1f} "
            f"p99={m['admission_wait_p99']:6.1f}  preempt={m['preemptions']:2d} "
            f"(swap {m['swaps']}/rec {m['recomputes']})  "
            f"swapB={m['swap_bytes']}  recTok={m['recompute_tokens']}  "
            f"lat mean={m['mean_latency_steps']:6.1f}"
        )
    print(
        f"  incremental admits {report['pressure']['admission_wait_saving']:.2f}x "
        f"earlier than full-need admission (identical token streams)"
    )
    print("\nspeculative (draft tier x spec_k, identical token streams):")
    for s in report["speculative"]["settings"]:
        if s["spec_k"] == 0:
            print(f"  {s['setting']:12s} drain={s['drain_ticks']:4d} ticks")
        else:
            print(
                f"  {s['setting']:12s} drain={s['drain_ticks']:4d} ticks  "
                f"accept={s['draft_acceptance_rate']:.2f}  "
                f"tok/tick={s['accepted_tokens_per_tick']:.2f}  "
                f"rollbacks={s['rollbacks']}"
            )
    mp = report["mixed_policy"]
    print("\nmixed policy (greedy + sampled + per-request density + spec in one batch):")
    print(
        f"  tokens by policy: {mp['tokens_by_policy']}  drain={mp['drain_ticks']} ticks  "
        f"spec accept={mp['draft_acceptance_rate']:.2f}"
    )
    print(
        f"  replay identical: {mp['replay_identical']}  "
        f"schedule-invariant sampled streams: {mp['schedule_invariant']}"
    )
    sp = report["shared_prefix"]
    sh = sp["headline"]
    print("\nshared prefix (one system prompt fanned out, identical token streams):")
    print(
        f"  hit rate={sh['hit_rate']:.2f}  "
        f"prefill tokens saved={sh['prefill_tokens_saved_frac'] * 100:.0f}%  "
        f"kv rows x ticks/token: {sh['kv_row_ticks_saving_cached_vs_uncached']:.2f}x less  "
        f"peak kv rows: {sh['peak_kv_rows_saving']:.2f}x less"
    )
    cs = report["cluster"]
    print("\ncluster (N replica shards vs one big engine, identical token streams):")
    for s in cs["setups"]:
        print(
            f"  {s['setup']:12s} drain={s['drain_ticks']:4d} ticks  "
            f"admit p99={s['admission_wait_p99']:6.1f}  "
            f"migrations={s['migrations']} ({s['migration_bytes']}B)  "
            f"occ var={s['occupancy_variance']:8.1f}"
        )
    ch = cs["headline"]
    print(
        f"  balanced admits {ch['wait_p99_saving_balanced_vs_rr']:.2f}x earlier (p99) "
        f"than round-robin under the hot-spot workload"
    )
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nwrote {OUT_JSON}")
