"""Roofline reporter: aggregates dry-run JSON records into the per-cell
three-term table (EXPERIMENTS.md §Roofline) and computes before/after deltas
for the §Perf hillclimb log.

Usage:
    python -m benchmarks.roofline [--dir benchmarks/dryrun_results] [--csv]
    python -m benchmarks.roofline --compare baseline_dir new_dir
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List


def load_records(directory: str | Path) -> List[Dict]:
    recs = []
    for p in sorted(Path(directory).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_table(recs: List[Dict], csv: bool = False) -> str:
    cols = [
        "arch", "shape", "mesh", "compute_ms", "memory_ms", "collective_ms",
        "bottleneck", "useful", "mem_GiB", "fits", "roofline_frac",
    ]
    rows = []
    for r in recs:
        t = r["roofline_terms_s"]
        dom = max(t.values())
        frac = t["compute_s"] / dom if dom > 0 else 0.0
        mesh = "x".join(str(v) for v in r["mesh"].values())
        rows.append([
            r["arch"], r["shape"], mesh,
            f"{t['compute_s'] * 1e3:.1f}", f"{t['memory_s'] * 1e3:.1f}",
            f"{t['collective_s'] * 1e3:.1f}", r["bottleneck"].replace("_s", ""),
            f"{(r.get('useful_flops_ratio') or 0):.2f}",
            f"{r['memory']['peak_bytes'] / 1024**3:.1f}",
            "y" if r["fits_hbm_16g"] else "n",
            f"{frac:.2f}",
        ])
    if csv:
        out = [",".join(cols)]
        out += [",".join(str(c) for c in row) for row in rows]
        return "\n".join(out)
    widths = [max(len(str(x)) for x in [c] + [row[i] for row in rows]) for i, c in enumerate(cols)]
    lines = ["| " + " | ".join(c.ljust(w) for c, w in zip(cols, widths)) + " |"]
    lines.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    for row in rows:
        lines.append("| " + " | ".join(str(c).ljust(w) for c, w in zip(row, widths)) + " |")
    return "\n".join(lines)


def compare(base_dir: str, new_dir: str) -> str:
    base = {(r["arch"], r["shape"]): r for r in load_records(base_dir)}
    new = {(r["arch"], r["shape"]): r for r in load_records(new_dir)}
    lines = ["arch,shape,term,before_ms,after_ms,delta_pct"]
    for key in sorted(set(base) & set(new)):
        b, n = base[key], new[key]
        for term in ("compute_s", "memory_s", "collective_s"):
            tb = b["roofline_terms_s"][term] * 1e3
            tn = n["roofline_terms_s"][term] * 1e3
            if tb > 0:
                lines.append(
                    f"{key[0]},{key[1]},{term},{tb:.1f},{tn:.1f},{100 * (tn - tb) / tb:+.1f}"
                )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/dryrun_results")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "NEW"))
    args = ap.parse_args()
    if args.compare:
        print(compare(*args.compare))
        return
    print(fmt_table(load_records(args.dir), csv=args.csv))


if __name__ == "__main__":
    main()
