"""Decode speed benchmark (Fig. 5 analogue).

On-device wall-clock speedups are phone numbers in the paper; here we report
(a) measured CPU wall-time of dense vs GLASS-compact decode steps on the
tiny model (the compute-reduction effect), and (b) the analytic decode-step
byte/FLOP reductions for each assigned architecture at 50% density (the
memory-residency effect that dominated the paper's Gemma-7B 11x case).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.core import GlassConfig, build_masks, compact_params
from repro.launch.specs import compact_config

from .common import TINY_LLAMA, build_bundle


def _time_step(fn, *args, iters=30) -> float:
    fn(*args)  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def measured_speedup() -> Tuple[List[dict], float]:
    b = build_bundle(TINY_LLAMA, n_samples=2)
    model, params = b.model, b.params
    toks = b.sequences[0][:, :8]
    B, S = toks.shape
    logits, cache, stats = model.prefill(params, {"tokens": toks}, 64)
    masks = build_masks(stats, b.priors["A_nps"], GlassConfig(density=0.5))
    compact = compact_params(model, params, masks.idx)
    tok = toks[:, :1]

    dense_fn = jax.jit(lambda p, c, t: model.decode_step(p, t, c, jnp.int32(8)))
    comp_fn = jax.jit(
        lambda p, c, t, cl: model.decode_step(p, t, c, jnp.int32(8), compact_layers=cl)
    )
    t_dense = _time_step(lambda p, c, t: dense_fn(p, c, t)[0], params, cache, tok)
    t_comp = _time_step(lambda p, c, t: comp_fn(p, c, t, compact)[0], params, cache, tok)
    rows = [dict(step="dense", us=t_dense), dict(step="glass_compact", us=t_comp)]
    return rows, t_dense / t_comp


def analytic_reductions(density: float = 0.5) -> Tuple[List[dict], float]:
    """Per assigned arch: decode-step FFN weight-bytes + FLOPs at 50%."""
    rows = []
    ratios = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        dcfg = compact_config(cfg, density)
        full, comp = cfg.n_active_params(), dcfg.n_active_params()
        rows.append(
            dict(
                arch=arch,
                active_params_dense=full,
                active_params_glass=comp,
                decode_bytes_ratio=comp / full,
            )
        )
        ratios.append(full / comp)
    return rows, float(np.mean(ratios))
