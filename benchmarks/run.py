"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract): us_per_call
is the wall time of producing the table; ``derived`` is its headline metric.
Detailed rows are written to benchmarks/results/*.json.

Each table runs in its own subprocess: the XLA CPU ORC JIT in this container
intermittently fails ("Failed to materialize symbols") after many hundreds
of compilations in one process; per-table isolation + on-disk caching of the
trained models / priors / dense trajectories keeps the harness robust and
restartable.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

TABLES = [
    ("table2_ppl_kld_imp_pct", "tables", "table2_ppl_kld"),
    ("table3_nps_beats_corpus_pct", "tables", "table3_density_sweep"),
    ("table5_jaccard_fused_minus_single", "tables", "table5_oracle_jaccard"),
    ("table6_fused_ppl_imp_pct", "tables", "table6_lambda_ablation"),
    ("fig4_best_lambda", "tables", "fig4_lambda_sweep"),
    ("table1_shortgen_absdiff", "tables", "table1_short_tasks"),
    ("fig5_measured_decode_speedup", "decode_bench", "measured_speedup"),
    ("fig5_analytic_byte_reduction", "decode_bench", "analytic_reductions"),
    ("serve_continuous_latency_speedup", "serve_bench", "serve_throughput"),
]

_WORKER = """
import json, sys
from benchmarks import {module}
rows, derived = {module}.{func}()
print("RESULT_JSON:" + json.dumps({{"rows": rows, "derived": derived}}))
"""


def _run(name: str, module: str, func: str) -> None:
    t0 = time.perf_counter()
    env = dict(os.environ)
    root = Path(__file__).parent.parent
    env["PYTHONPATH"] = f"{root / 'src'}{os.pathsep}{root}" + os.pathsep + env.get("PYTHONPATH", "")
    # single codegen dylib: works around intermittent ORC-JIT symbol
    # materialization failures in this container's XLA CPU backend
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + " --xla_cpu_parallel_codegen_split_count=1"
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER.format(module=module, func=func)],
        capture_output=True, text=True, env=env, timeout=3600, cwd=root,
    )
    us = (time.perf_counter() - t0) * 1e6
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT_JSON:"):
            payload = json.loads(line[len("RESULT_JSON:"):])
    if proc.returncode != 0 or payload is None:
        print(f"{name},{us:.0f},FAILED", flush=True)
        sys.stderr.write(proc.stderr[-2000:] + "\n")
        return
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))
    print(f"{name},{us:.0f},{payload['derived']:.4f}", flush=True)


def main() -> None:
    print("name,us_per_call,derived")
    for name, module, func in TABLES:
        _run(name, module, func)


if __name__ == "__main__":
    main()
