"""§Perf hillclimb runner: three chosen cells, hypothesis-driven variants.

Each variant is lowered+compiled on the production mesh and recorded under
benchmarks/perf/<cell>__<variant>.json; the EXPERIMENTS.md §Perf log is
written from these records.  Run AFTER the baseline sweep:

    PYTHONPATH=src python -m benchmarks.perf_iterations
"""
from __future__ import annotations

import json
from pathlib import Path

OUT = Path(__file__).parent / "perf"

# (cell-name, arch, shape, run-kwargs)
EXPERIMENTS = [
    # -- cell 1: gemma2-9b train_4k — worst useful ratio among dense archs.
    # H1: baseline TP-16 moves ~4d bytes/token/layer over ICI vs 2df/16 flops
    #     -> ~4.6x comm/compute. Remap: pure DP over all 256 chips with
    #     ZeRO-3/FSDP weights. Predicted: collective ~= 2*params*2B gathers
    #     (~2.3 s) instead of 13.3 s; compute unchanged.
    ("gemma2-9b__train_4k", "gemma2-9b", "train_4k",
     dict(mode_override=dict(pure_dp=True, grad_accum=1, fsdp=True))),
    # H2: stay TP but save dot outputs (remat=dots): backward skips the
    #     recompute all-reduces. Predicted: collective -1/3, memory +~50%.
    ("gemma2-9b__train_4k", "gemma2-9b", "train_4k",
     dict(variant="remat_dots", mode_override=dict(grad_accum=2, remat="dots"))),
    # H3: Megatron-style sequence parallelism on the residual stream:
    #     all-gather/reduce-scatter at block edges replaces the fwd ARs
    #     (same bytes) but the layer-input stash shards 16x. Predicted:
    #     memory -~8 GiB, collective ~flat.
    ("gemma2-9b__train_4k", "gemma2-9b", "train_4k",
     dict(variant="seq_parallel", mode_override=dict(grad_accum=2, seq_parallel=True))),

    # -- cell 2: grok-1-314b train_4k — most collective-bound cell.
    # H4: 8 experts don't divide data=16 -> baseline FSDP-gathers expert
    #     weights (hoisted out of the loop by XLA). x2 expert replication
    #     (DeepSeek-V3 style) = 16 slots = clean EP over data. Predicted:
    #     collective drops by order(s) of magnitude; memory ~2x expert
    #     weights/16 (afffordable).
    ("grok-1-314b__train_4k", "grok-1-314b", "train_4k",
     dict(variant="expert_rep2", mode_override=dict(expert_replication=2, grad_accum=16, fsdp=True))),
    # H5: same fix applied to serving (prefill was 214 s collective).
    ("grok-1-314b__prefill_32k", "grok-1-314b", "prefill_32k",
     dict(variant="expert_rep2", mode_override=dict(expert_replication=2))),
    ("grok-1-314b__decode_32k", "grok-1-314b", "decode_32k",
     dict(variant="expert_rep2", mode_override=dict(expert_replication=2))),

    # -- cell 3: gemma2-27b decode_32k — the paper's own technique cell.
    # H6: decode is memory-bound: bytes = params + KV cache. GLASS@0.5
    #     halves the param term (paper-faithful). Dense baseline quantifies
    #     the gain; density 0.25 probes the beyond-paper limit where the
    #     cache term dominates.
    ("gemma2-27b__decode_32k", "gemma2-27b", "decode_32k",
     dict(variant="dense_baseline", density=None)),
    ("gemma2-27b__decode_32k", "gemma2-27b", "decode_32k",
     dict(variant="glass_d25", density=0.25)),
]


def main():
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    OUT.mkdir(parents=True, exist_ok=True)
    for cell, arch, shape, kw in EXPERIMENTS:
        variant = kw.pop("variant", "variant")
        sub = OUT / f"{cell}__{variant}"
        try:
            rec = run_cell(arch, shape, mesh, sub, **kw)
        except Exception as e:  # noqa: BLE001
            print(f"[perf] FAIL {cell} {variant}: {e}", flush=True)
            continue
        t = rec["roofline_terms_s"]
        print(
            f"[perf] {cell:28s} {variant:16s} c={t['compute_s']*1e3:9.1f}ms "
            f"m={t['memory_s']*1e3:7.1f}ms coll={t['collective_s']*1e3:9.1f}ms "
            f"mem={rec['memory']['peak_bytes']/1024**3:6.1f}GiB useful={rec['useful_flops_ratio']:.2f}",
            flush=True,
        )


if __name__ == "__main__":
    main()
