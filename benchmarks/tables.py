"""One benchmark function per paper table/figure (reduced scale, same
protocol).  Each returns (rows, derived) where ``derived`` is the headline
number the CSV reports.

  table1  — classification / short-generation parity (proxy: next-token
            accuracy + long-prompt PPL, GLASS vs GRIFFIN)
  table2  — PPL + top-100 KLD at 50% density: GRIFFIN vs A/I-GLASS (NPS)
  table3  — density sweep 90..10: NPS prior vs corpus prior
  table5  — oracle-overlap Jaccard: Local / Global / Global-Local
  table6  — lambda ablation {0, 0.5, 1} end-to-end PPL
  fig4    — lambda sensitivity sweep
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GlassConfig, build_masks
from repro.core.oracle import jaccard_vs_oracle, oracle_masks

from .common import TINY_GEMMA, TINY_LLAMA, EvalBundle, build_bundle, sparse_eval_logits
from .metrics import dense_trajectory_ppl, token_accuracy, top100_kld

_BUNDLES: Dict[str, EvalBundle] = {}


def bundle(name: str = "llama") -> EvalBundle:
    if name not in _BUNDLES:
        cfg = {"llama": TINY_LLAMA, "gemma": TINY_GEMMA}[name]
        _BUNDLES[name] = build_bundle(cfg)
    return _BUNDLES[name]


def _eval_variant(b: EvalBundle, prior_key: str | None, lam: float, density: float) -> Tuple[float, float]:
    """Mean (PPL, KLD) across samples for one GLASS variant."""
    gcfg = GlassConfig(density=density, lam=lam)
    prior = b.priors[prior_key] if prior_key else b.priors["A_nps"]
    ppls, klds = [], []
    for seq, dl in zip(b.sequences, b.dense_logits):
        sl = sparse_eval_logits(b.model, b.params, seq, b.prompt_len, prior, gcfg)
        ppls.append(dense_trajectory_ppl(sl, seq[0], b.prompt_len))
        klds.append(top100_kld(dl, sl, b.prompt_len))
    return float(np.mean(ppls)), float(np.mean(klds))


def table2_ppl_kld() -> Tuple[List[dict], float]:
    """GRIFFIN vs A-GLASS vs I-GLASS at 50% density (both tiny models)."""
    rows = []
    best_imp = 0.0
    for mname in ("llama",):  # single backbone at mixture scale (CPU budget)
        b = bundle(mname)
        grf_ppl, grf_kld = _eval_variant(b, None, lam=0.0, density=0.5)
        for variant, key in [("A-GLASS", "A_nps"), ("I-GLASS", "I_nps")]:
            ppl, kld = _eval_variant(b, key, lam=0.5, density=0.5)
            imp_ppl = 100.0 * (grf_ppl - ppl) / grf_ppl
            imp_kld = 100.0 * (grf_kld - kld) / grf_kld
            best_imp = max(best_imp, imp_ppl)
            rows.append(dict(model=mname, variant=variant, ppl=ppl, kld=kld,
                             griffin_ppl=grf_ppl, griffin_kld=grf_kld,
                             imp_ppl_pct=imp_ppl, imp_kld_pct=imp_kld))
    return rows, best_imp


def _table3_row(density: float) -> dict:
    b = bundle("llama")
    _, grf = _eval_variant(b, None, lam=0.0, density=density)
    row = dict(density=density, griffin_kld=grf)
    for variant in ("A", "I"):
        for src in ("nps", "corpus"):
            _, kld = _eval_variant(b, f"{variant}_{src}", lam=0.5, density=density)
            row[f"{variant}_{src}_kld"] = kld
    return row


def table3_density_sweep() -> Tuple[List[dict], float]:
    """KLD across densities 90..10: GRIFFIN vs A/I-GLASS x {NPS, corpus}.

    One subprocess per density: this is the heaviest table (25 variant
    evaluations x 16 samples) and the container's XLA CPU ORC JIT fails
    intermittently past a few hundred compiled programs per process."""
    import json as _json
    import os
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{root / 'src'}{os.pathsep}{root}{os.pathsep}" + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + " --xla_cpu_parallel_codegen_split_count=1"
    rows = []
    for density in (0.9, 0.7, 0.5, 0.3, 0.1):
        code = (
            "import json\nfrom benchmarks.tables import _table3_row\n"
            f"print('ROW:' + json.dumps(_table3_row({density})))\n"
        )
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True, env=env,
            timeout=1800, cwd=root,
        )
        for line in proc.stdout.splitlines():
            if line.startswith("ROW:"):
                rows.append(_json.loads(line[4:]))
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-1500:])
    nps_wins = sum(
        1 for r in rows for v in ("A", "I") if r[f"{v}_nps_kld"] <= r[f"{v}_corpus_kld"]
    )
    return rows, 100.0 * nps_wins / (2 * len(rows))


def table5_oracle_jaccard() -> Tuple[List[dict], float]:
    """Jaccard to the decoding-time oracle set at 50% sparsity."""
    b = bundle("llama")
    res = {"local": [], "global": [], "fused": []}
    for seq in b.sequences:
        _, orc = oracle_masks(b.model, b.params, seq, b.prompt_len, density=0.5)
        _, _, stats = b.model.prefill(b.params, {"tokens": seq[:, : b.prompt_len]}, b.prompt_len + 1)
        for name, lam in [("local", 0.0), ("global", 1.0), ("fused", 0.5)]:
            ms = build_masks(stats, b.priors["A_nps"], GlassConfig(density=0.5, lam=lam))
            res[name].append(float(jaccard_vs_oracle(ms.mask, orc)["mean"]))
    rows = [
        dict(variant=k, mean_jaccard=float(np.mean(v)), std=float(np.std(v)))
        for k, v in res.items()
    ]
    fused = float(np.mean(res["fused"]))
    single = max(float(np.mean(res["local"])), float(np.mean(res["global"])))
    return rows, fused - single


def table6_lambda_ablation() -> Tuple[List[dict], float]:
    b = bundle("llama")
    rows = []
    ppls = {}
    for name, lam in [("local_only", 0.0), ("global_only", 1.0), ("fused", 0.5)]:
        ppl, kld = _eval_variant(b, "I_nps", lam=lam, density=0.5)
        ppls[name] = ppl
        rows.append(dict(variant=name, lam=lam, ppl=ppl, kld=kld))
    imp = 100.0 * (ppls["local_only"] - ppls["fused"]) / ppls["local_only"]
    return rows, imp


def fig4_lambda_sweep() -> Tuple[List[dict], float]:
    b = bundle("llama")
    rows = []
    for lam in np.linspace(0.0, 1.0, 11):
        ppl, _ = _eval_variant(b, "I_nps", lam=float(lam), density=0.5)
        rows.append(dict(lam=round(float(lam), 2), ppl=ppl))
    best = min(rows, key=lambda r: r["ppl"])
    return rows, best["lam"]


def table1_short_tasks() -> Tuple[List[dict], float]:
    """Classification/short-gen parity proxy: with long prompts, GLASS and
    GRIFFIN should be nearly identical (paper Tab. 1)."""
    b = bundle("llama")
    rows = []
    diffs = []
    # long-prompt regime: use the dense trajectory itself as "prompt"
    for seq, dl in zip(b.sequences[:8], b.dense_logits[:8]):
        long_pl = seq.shape[1] - 8
        _, _, stats = b.model.prefill(b.params, {"tokens": seq[:, :long_pl]}, long_pl + 1)
        accs = {}
        for name, lam in [("griffin", 0.0), ("glass", 0.5)]:
            ms = build_masks(stats, b.priors["I_nps"], GlassConfig(density=0.5, lam=lam))
            sl = b.model.logits(b.params, {"tokens": seq}, ffn_masks=ms.mask)[0]
            accs[name] = token_accuracy(sl[long_pl - 1 : -1], seq[0, long_pl:])
        diffs.append(abs(accs["glass"] - accs["griffin"]))
        rows.append(dict(sample=len(rows), griffin_acc=accs["griffin"], glass_acc=accs["glass"]))
    return rows, float(np.mean(diffs))
